//! Readiness polling for the evented front-end, dependency-free.
//!
//! [`Poller`] wraps the OS readiness API behind one tiny interface:
//! `epoll(7)` on Linux (O(ready) wakeups, the C100K path) and portable
//! `poll(2)` on other unix (O(registered) per wait, correct everywhere).
//! Both are reached through direct `extern "C"` declarations against the
//! libc that `std` already links — the offline build adds no crates.
//!
//! Level-triggered on both backends: an event fires as long as the fd is
//! ready, so a handler that drains until `WouldBlock` never misses data
//! and a handler interrupted early is simply re-notified.  Error and
//! hang-up conditions are folded into `readable` — the next `read()`
//! observes the actual error/EOF, which keeps the connection state
//! machine single-pathed.

#![allow(clippy::upper_case_acronyms)]

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// One readiness event: which registered token fired and how.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PollEvent {
    /// The token the fd was registered under.
    pub(crate) token: u64,
    /// The fd is readable (or errored/hung up — reads surface that).
    pub(crate) readable: bool,
    /// The fd accepts writes without blocking.
    pub(crate) writable: bool,
}

/// Clamp an optional wait to the millisecond int the syscalls take
/// (`None` = block forever; sub-millisecond waits round up to 1 ms so a
/// positive timeout can never spin at zero).
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) if d.is_zero() => 0,
        Some(d) => d.as_millis().clamp(1, i32::MAX as u128) as i32,
    }
}

#[cfg(target_os = "linux")]
pub(crate) use linux::Poller;

#[cfg(target_os = "linux")]
mod linux {
    use super::{PollEvent, timeout_ms};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    // the kernel ABI struct; packed on x86-64 (and only there), exactly
    // as <sys/epoll.h> declares it
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// `epoll`-backed readiness poller.
    pub(crate) struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        /// Create the epoll instance.
        pub(crate) fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
        }

        fn ctl(
            &mut self,
            op: i32,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            let mut events = EPOLLRDHUP;
            if read {
                events |= EPOLLIN;
            }
            if write {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(())
            }
        }

        /// Register `fd` under `token` with the given interest.
        pub(crate) fn add(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, read, write)
        }

        /// Change a registered fd's token/interest.
        pub(crate) fn modify(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, read, write)
        }

        /// Deregister `fd` (must still be open — deregister *before*
        /// dropping the socket).
        pub(crate) fn remove(&mut self, fd: RawFd) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) };
            if rc < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(())
            }
        }

        /// Wait for readiness, appending into `out` (which is cleared
        /// first).  A signal or timeout returns cleanly with no events.
        pub(crate) fn wait(
            &mut self,
            out: &mut Vec<PollEvent>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as i32,
                    timeout_ms(timeout),
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in &self.buf[..n as usize] {
                // copy out of the (possibly packed) ABI struct first
                let events = ev.events;
                let token = ev.data;
                out.push(PollEvent {
                    token,
                    readable: events & (EPOLLIN | EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                    writable: events & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
pub(crate) use fallback::Poller;

#[cfg(not(target_os = "linux"))]
mod fallback {
    use super::{PollEvent, timeout_ms};
    use std::collections::BTreeMap;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    }

    /// Portable `poll(2)` readiness poller: the registration table lives
    /// in userspace and is rebuilt into a `pollfd` array per wait —
    /// O(registered) per call, which is fine at fallback scale.
    pub(crate) struct Poller {
        interest: BTreeMap<RawFd, (u64, bool, bool)>,
    }

    impl Poller {
        /// Create an empty registration table.
        pub(crate) fn new() -> io::Result<Poller> {
            Ok(Poller { interest: BTreeMap::new() })
        }

        /// Register `fd` under `token` with the given interest.
        pub(crate) fn add(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.interest.insert(fd, (token, read, write));
            Ok(())
        }

        /// Change a registered fd's token/interest.
        pub(crate) fn modify(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.interest.insert(fd, (token, read, write));
            Ok(())
        }

        /// Deregister `fd`.
        pub(crate) fn remove(&mut self, fd: RawFd) -> io::Result<()> {
            self.interest.remove(&fd);
            Ok(())
        }

        /// Wait for readiness, appending into `out` (cleared first).
        pub(crate) fn wait(
            &mut self,
            out: &mut Vec<PollEvent>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = self
                .interest
                .iter()
                .map(|(&fd, &(_, read, write))| PollFd {
                    fd,
                    events: if read { POLLIN } else { 0 } | if write { POLLOUT } else { 0 },
                    revents: 0,
                })
                .collect();
            if fds.is_empty() {
                // nothing registered: just sleep out the timeout
                if let Some(d) = timeout {
                    std::thread::sleep(d);
                }
                return Ok(());
            }
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms(timeout)) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for pfd in &fds {
                if pfd.revents == 0 {
                    continue;
                }
                let (token, _, _) = self.interest[&pfd.fd];
                out.push(PollEvent {
                    token,
                    readable: pfd.revents & (POLLIN | POLLERR | POLLHUP) != 0,
                    writable: pfd.revents & (POLLOUT | POLLERR | POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

/// Compile-time assertion helper: both backends expose the same shape.
#[allow(dead_code)]
fn _assert_interface(p: &mut Poller, out: &mut Vec<PollEvent>) -> io::Result<()> {
    let fd: RawFd = -1;
    let _ = p.add(fd, 0, true, false);
    let _ = p.modify(fd, 0, true, true);
    let _ = p.remove(fd);
    p.wait(out, Some(Duration::from_millis(0)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn readiness_tracks_data_and_interest() {
        let (mut a, mut b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        let mut poller = Poller::new().unwrap();
        poller.add(a.as_raw_fd(), 7, true, false).unwrap();

        // nothing to read yet: a short wait returns no event for fd a
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));

        // peer writes: fd a must become readable under its token
        b.write_all(b"x").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "readable event never fired");
        }
        let mut buf = [0u8; 8];
        assert_eq!(a.read(&mut buf).unwrap(), 1);

        // write interest on an idle socket fires immediately
        poller.modify(a.as_raw_fd(), 7, true, true).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(1))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.writable));

        poller.remove(a.as_raw_fd()).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().all(|e| e.token != 7));
    }
}
