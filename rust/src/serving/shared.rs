//! Logic shared by both serving front-ends.
//!
//! The thread-per-connection server ([`crate::serving::net`]) and the
//! evented server ([`crate::serving::evented`]) must be two transports
//! over *one* behavior: same admission control, same request validation,
//! same typed errors, same metrics semantics.  This module is that
//! behavior — everything here is transport-agnostic, and the e2e suite
//! runs every scenario against both servers to keep it that way.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::InferenceResponse;
use crate::coordinator::server::Coordinator;
use crate::serving::proto::{
    ErrorCode, ErrorFrame, Frame, InferFrame, InferOkFrame, MetricsFrame, ModelsFrame, NetCounters,
    TraceEventWire, TraceFrame,
};
use crate::tensor::Tensor;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// The serving layer shares connection tables and write halves across
/// threads; a panic while holding one of those locks poisons it, and the
/// default `unwrap()` would then cascade the panic into every other
/// connection touching the same mutex — one bad request taking down the
/// whole accept loop.  All serving-layer lock sites go through this
/// helper instead: the protected data is counters and socket handles,
/// which stay structurally valid even if a holder died mid-update.
pub(crate) fn lock_unpoisoned<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Monotonic counters of the network layer (all atomic; shared by every
/// connection and snapshotted into the `metrics` frame together with the
/// open/inflight gauges the owning server tracks).
#[derive(Debug, Default)]
pub(crate) struct NetMetrics {
    pub(crate) connections_opened: AtomicU64,
    pub(crate) connections_rejected: AtomicU64,
    pub(crate) frames_received: AtomicU64,
    pub(crate) frames_sent: AtomicU64,
    pub(crate) idle_reaped: AtomicU64,
    pub(crate) loris_reaped: AtomicU64,
    pub(crate) overload_rejections: AtomicU64,
    pub(crate) protocol_errors: AtomicU64,
    pub(crate) requests_failed: AtomicU64,
    pub(crate) requests_ok: AtomicU64,
}

impl NetMetrics {
    /// One consistent snapshot, combined with the caller's gauges.
    pub(crate) fn snapshot(&self, open: usize, inflight: usize) -> NetCounters {
        NetCounters {
            connections_open: open as u64,
            connections_opened: self.connections_opened.load(Ordering::SeqCst),
            connections_rejected: self.connections_rejected.load(Ordering::SeqCst),
            frames_received: self.frames_received.load(Ordering::SeqCst),
            frames_sent: self.frames_sent.load(Ordering::SeqCst),
            idle_reaped: self.idle_reaped.load(Ordering::SeqCst),
            inflight: inflight as u64,
            loris_reaped: self.loris_reaped.load(Ordering::SeqCst),
            overload_rejections: self.overload_rejections.load(Ordering::SeqCst),
            protocol_errors: self.protocol_errors.load(Ordering::SeqCst),
            requests_failed: self.requests_failed.load(Ordering::SeqCst),
            requests_ok: self.requests_ok.load(Ordering::SeqCst),
        }
    }
}

/// RAII slot of the in-flight admission gauge.  Owned (the gauge rides
/// an `Arc`) so a slot can outlive the stack frame that acquired it —
/// the evented server parks slots inside connection state and completion
/// messages until the response bytes are actually flushed.
pub(crate) struct InflightSlot(Arc<AtomicUsize>);

impl InflightSlot {
    /// Take a slot unless the gauge is at `cap`.
    pub(crate) fn acquire(gauge: &Arc<AtomicUsize>, cap: usize) -> Option<InflightSlot> {
        gauge
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                if n < cap { Some(n + 1) } else { None }
            })
            .ok()
            .map(|_| InflightSlot(Arc::clone(gauge)))
    }
}

impl Drop for InflightSlot {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// An `infer` frame that passed validation, ready to submit.
pub(crate) struct ValidInfer {
    /// Client-chosen request id, echoed in the reply.
    pub(crate) id: u64,
    /// Pre-checked registry model (`None` = default model).
    pub(crate) model: Option<String>,
    /// The image tensor built from the frame's dims/data.
    pub(crate) image: Tensor<f32>,
    /// Absolute deadline derived from the frame's `deadline_ms`, anchored
    /// at frame receipt (`None` = wait forever).
    pub(crate) deadline: Option<Instant>,
}

/// Validate an admitted `infer` frame: dims/data consistency, finiteness,
/// and a registry pre-check of the named model (a deterministic typed
/// error instead of the engine's post-batching stringly one).
pub(crate) fn validate_infer(req: InferFrame, coord: &Coordinator) -> Result<ValidInfer, Frame> {
    let id = Some(req.id);
    let err = |code: ErrorCode, msg: String| Frame::Error(ErrorFrame::new(id, code, msg));

    // checked product: a crafted dims array must not wrap around to a
    // plausible volume (or panic the thread in a debug build)
    let volume = req.dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d));
    let valid = matches!(volume, Some(v) if req.dims.len() == 3 && v > 0 && v == req.data.len());
    if !valid {
        return Err(err(
            ErrorCode::BadImage,
            format!(
                "dims {:?} do not describe the {}-element data array",
                req.dims,
                req.data.len()
            ),
        ));
    }
    if !req.data.iter().all(|x| x.is_finite()) {
        return Err(err(ErrorCode::BadImage, "image data contains non-finite values".into()));
    }
    if let Some(model) = &req.model {
        match coord.registry() {
            Some(reg) => {
                if reg.get(model).is_none() {
                    return Err(err(
                        ErrorCode::UnknownModel,
                        format!("model '{model}' is not in the registry"),
                    ));
                }
            }
            None => {
                return Err(err(
                    ErrorCode::UnknownModel,
                    format!("request names model '{model}' but the server has no registry"),
                ));
            }
        }
    }
    let image = Tensor::from_vec(&req.dims, req.data);
    let deadline = req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
    Ok(ValidInfer { id: req.id, model: req.model, image, deadline })
}

/// The `infer_ok` reply for a completed request.
pub(crate) fn infer_ok_frame(id: u64, resp: InferenceResponse) -> Frame {
    Frame::InferOk(InferOkFrame {
        id,
        model: resp.model.as_deref().map(str::to_string),
        logits: resp.logits,
        predicted: resp.predicted,
        queue_us: resp.queue_us,
        compute_us: resp.compute_us,
        batch_size: resp.batch_size,
        batch_occupancy: resp.batch_occupancy,
        hw: resp.hw,
    })
}

/// The typed `error` reply for a request that failed after admission.
/// The coordinator reports failures as strings; keep the wire error
/// typed by recognizing the messages that have a dedicated code: a
/// hot-removed model losing the registry pre-check race, a deadline the
/// batcher purged, and a request stranded by a dying shard worker (the
/// retryable case — the supervisor respawns the shard).
pub(crate) fn infer_err_frame(id: u64, msg: String) -> Frame {
    let code = if msg.contains("is not in the registry") {
        ErrorCode::UnknownModel
    } else if msg.contains("deadline exceeded") {
        ErrorCode::DeadlineExceeded
    } else if msg.contains("worker died") || msg.contains("unavailable") {
        ErrorCode::Unavailable
    } else {
        ErrorCode::Internal
    };
    Frame::Error(ErrorFrame::new(Some(id), code, msg))
}

/// The `models` reply to a `list_models` frame.
pub(crate) fn models_frame(coord: &Coordinator) -> Frame {
    Frame::Models(ModelsFrame {
        models: coord.registry().map(|r| r.names()).unwrap_or_default(),
        default: coord.default_model().map(str::to_string),
    })
}

/// The `metrics` reply to a `get_metrics` frame: merged across the shard
/// pool, plus the per-shard counters — the only place sharding is
/// visible on the wire.  One consistent snapshot: the counters must sum
/// to the merged totals even under live traffic.
pub(crate) fn metrics_frame(coord: &Coordinator, net: NetCounters) -> Frame {
    // one read of every shard's metrics: the merged aggregate, the
    // per-shard counters, and the per-shard stage histograms all derive
    // from the same snapshot, so they stay mutually consistent
    let per_shard = coord.shard_metrics();
    let mut m = Metrics::new();
    for s in &per_shard {
        m.merge(s);
    }
    Frame::Metrics(MetricsFrame {
        backend: m.backend.clone(),
        requests: m.requests,
        batches: m.batches,
        failed_batches: m.failed_batches,
        deadline_misses: m.deadline_misses,
        shard_restarts: coord.shard_restarts(),
        stolen_batches: m.stolen_batches,
        donated_batches: m.donated_batches,
        replicas_installed: m.replicas_installed,
        replicas_evicted: m.replicas_evicted,
        p50_us: m.percentile_us(50.0),
        p90_us: m.percentile_us(90.0),
        p99_us: m.percentile_us(99.0),
        per_model: m.per_model.clone(),
        shards: per_shard.iter().map(Metrics::counters).collect(),
        latency: m.latency_histogram().clone(),
        stages: m.stages.clone(),
        model_stages: m.per_model_stages.clone(),
        shard_stages: per_shard.iter().map(|s| s.stages.clone()).collect(),
        net,
    })
}

/// Default cap on events in one `trace` reply.  Keeps the frame well
/// under [`crate::serving::proto::DEFAULT_MAX_FRAME_BYTES`] even with
/// large rings; an explicit `limit` above the cap is clamped to it.
pub(crate) const DEFAULT_TRACE_EVENT_LIMIT: usize = 4096;

/// The `trace` reply to a `get_trace` frame: a consistent snapshot of
/// the coordinator's lifecycle rings (empty when tracing is disabled),
/// optionally filtered to one request id, keeping the most recent
/// `limit` events in ascending time order.
pub(crate) fn trace_frame(coord: &Coordinator, id: Option<u64>, limit: Option<u64>) -> Frame {
    let mut events: Vec<TraceEventWire> = match coord.tracer() {
        None => Vec::new(),
        Some(t) => t
            .snapshot()
            .into_iter()
            .filter(|e| id.is_none_or(|want| e.id == want))
            .map(|e| TraceEventWire {
                id: e.id,
                shard: e.shard as u64,
                stage: e.stage,
                t_us: e.t_us,
                aux: e.aux,
            })
            .collect(),
    };
    let cap = limit
        .map(|l| (l as usize).min(DEFAULT_TRACE_EVENT_LIMIT))
        .unwrap_or(DEFAULT_TRACE_EVENT_LIMIT);
    if events.len() > cap {
        events.drain(..events.len() - cap);
    }
    Frame::Trace(TraceFrame { events })
}

/// Stable ordinal of an error code, recorded as the `retried` trace
/// event's aux word so a span dump shows *why* the server advised a
/// retry.  Follows the order the codes are documented in
/// `docs/WIRE_PROTOCOL.md`; 0 is reserved for "unknown".
pub(crate) fn error_code_ordinal(code: ErrorCode) -> u64 {
    match code {
        ErrorCode::InvalidFrame => 1,
        ErrorCode::UnsupportedVersion => 2,
        ErrorCode::UnknownType => 3,
        ErrorCode::BadImage => 4,
        ErrorCode::UnknownModel => 5,
        ErrorCode::ResourceExhausted => 6,
        ErrorCode::ShuttingDown => 7,
        ErrorCode::Internal => 8,
        ErrorCode::DeadlineExceeded => 9,
        ErrorCode::Unavailable => 10,
    }
}

/// What the tracer needs once the reply bytes are on the wire: the
/// owning shard, the coordinator-assigned request id (distinct from the
/// client's wire id), and the model label for the per-model write-back
/// histogram.  Produced only for infer frames that reached the
/// coordinator; both front-ends carry one alongside the reply.
pub(crate) struct ReplyTrace {
    pub(crate) shard: usize,
    pub(crate) coord_id: u64,
    pub(crate) model: Option<String>,
    /// Set when the reply is a retryable error: the span ends in a
    /// `retried` event (the client's retry arrives as a fresh span).
    pub(crate) retry_code: Option<ErrorCode>,
}

impl ReplyTrace {
    /// Stamp `retry_code` from the reply about to be written.
    pub(crate) fn observe(mut self, reply: &Frame) -> ReplyTrace {
        if let Frame::Error(e) = reply {
            if e.code.retryable() {
                self.retry_code = Some(e.code);
            }
        }
        self
    }

    /// Close the span: record the write-back stage (`took`, `bytes` on
    /// the wire) and, for retryable errors, the `retried` event.
    pub(crate) fn finish(&self, coord: &Coordinator, took: Duration, bytes: usize) {
        coord.record_reply_written(self.shard, self.coord_id, self.model.as_deref(), took, bytes);
        if let Some(code) = self.retry_code {
            coord.record_retry_advised(self.shard, self.coord_id, error_code_ordinal(code));
        }
    }
}

/// The reply to a frame type the server never accepts (server-to-client
/// frames arriving at the server).
pub(crate) fn wrong_direction_frame(frame: &Frame) -> Frame {
    Frame::Error(ErrorFrame::new(
        None,
        ErrorCode::InvalidFrame,
        format!("servers do not accept '{}' frames", frame.type_str()),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_unpoisoned_survives_a_panicked_holder() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "the lock must actually be poisoned");
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 8, "data survives the poisoned holder");
    }

    #[test]
    fn coordinator_failures_map_to_typed_codes() {
        let code = |msg: &str| match infer_err_frame(1, msg.to_string()) {
            Frame::Error(e) => e.code,
            other => panic!("expected error frame, got {other:?}"),
        };
        assert_eq!(code("model 'x' is not in the registry"), ErrorCode::UnknownModel);
        assert_eq!(
            code("deadline exceeded before batch launch (queued 5ms)"),
            ErrorCode::DeadlineExceeded
        );
        let died = "shard worker died before the request was served";
        assert_eq!(code(died), ErrorCode::Unavailable);
        let pending = "shard 0 unavailable (worker died; respawn pending)";
        assert_eq!(code(pending), ErrorCode::Unavailable);
        assert_eq!(code("kernel panic: index out of bounds"), ErrorCode::Internal);
    }

    #[test]
    fn inflight_slot_is_a_bounded_gauge() {
        let gauge = Arc::new(AtomicUsize::new(0));
        let a = InflightSlot::acquire(&gauge, 2).expect("first slot");
        let b = InflightSlot::acquire(&gauge, 2).expect("second slot");
        assert!(InflightSlot::acquire(&gauge, 2).is_none(), "cap enforced");
        assert_eq!(gauge.load(Ordering::SeqCst), 2);
        drop(a);
        assert_eq!(gauge.load(Ordering::SeqCst), 1);
        let c = InflightSlot::acquire(&gauge, 2).expect("freed slot reusable");
        drop(b);
        drop(c);
        assert_eq!(gauge.load(Ordering::SeqCst), 0);
    }
}
