//! The wire protocol: length-prefixed canonical-JSON frames.
//!
//! `docs/WIRE_PROTOCOL.md` is the normative spec; this module is the
//! reference implementation, and `tests/wire_protocol_doc.rs` keeps the
//! two in sync by round-tripping every example frame in the spec
//! byte-for-byte through [`decode`] + [`encode`].
//!
//! Framing: every frame is a 4-byte **big-endian** unsigned payload
//! length followed by that many bytes of UTF-8 JSON.  The JSON payload is
//! **canonical** ([`crate::runtime::json`]): compact, object keys sorted
//! lexicographically, floats in shortest round-trip decimal form, and
//! optional fields *omitted* rather than `null` — so a given [`Frame`]
//! value has exactly one byte encoding.  Every payload carries
//! `"v": 1` ([`PROTOCOL_VERSION`]) and a `"type"` tag; unknown versions
//! and types are rejected with typed [`ErrorFrame`]s, never by dropping
//! the connection.
//!
//! Numbers ride as JSON numbers (f64): integers are exact up to 2^53,
//! and `f32` tensor data survives the f32 → f64 → shortest-decimal →
//! f64 → f32 round trip bit-exactly (pinned by a test in
//! [`crate::runtime::json`]).

use crate::coordinator::cost::HwCost;
use crate::coordinator::metrics::{ModelCounters, ShardCounters};
use crate::obs::{LogHistogram, Stage, StageHistograms};
use crate::runtime::json::{self, Json};
use std::collections::BTreeMap;
use std::fmt;
use std::io::{Read, Write};

/// Protocol version every frame carries in its `"v"` field.  Additive,
/// backwards-compatible changes (new frame types, new optional fields)
/// keep the version; anything else bumps it, and a server rejects
/// mismatches with `UNSUPPORTED_VERSION`.
pub const PROTOCOL_VERSION: u64 = 1;

/// Default cap on one frame's payload size (1 MiB — a digits-model infer
/// frame is ~3 KiB, so this bounds a malicious or confused peer, not a
/// legitimate one).
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 20;

/// Machine-readable error category carried by [`ErrorFrame`].
///
/// The string forms (SCREAMING_SNAKE_CASE) are the wire encoding and are
/// part of the protocol spec — see `docs/WIRE_PROTOCOL.md` for when each
/// code is returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The payload was not valid canonical-JSON, was missing required
    /// fields, had wrong field types, or was a frame type the receiving
    /// side never accepts.
    InvalidFrame,
    /// The frame's `"v"` did not match [`PROTOCOL_VERSION`].
    UnsupportedVersion,
    /// The `"type"` tag names no known frame type.
    UnknownType,
    /// The infer request's `dims`/`data` are inconsistent, empty, or not
    /// finite numbers.
    BadImage,
    /// The named model is not in the server's registry (or the server
    /// serves no registry at all).
    UnknownModel,
    /// Admission control rejected the request: the server is at its
    /// in-flight request cap or connection cap.  Retryable by design.
    ResourceExhausted,
    /// The server is shutting down and no longer accepts work.
    ShuttingDown,
    /// Execution failed server-side (batch error or panic).
    Internal,
    /// The request's deadline expired before a reply could be produced
    /// (v1-additive).  Not retryable: the client's time budget is spent.
    DeadlineExceeded,
    /// The serving path was transiently unavailable — e.g. a shard
    /// worker died before the request was served and is being respawned
    /// (v1-additive).  Retryable by design.
    Unavailable,
}

impl ErrorCode {
    /// The wire encoding of this code.
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::InvalidFrame => "INVALID_FRAME",
            ErrorCode::UnsupportedVersion => "UNSUPPORTED_VERSION",
            ErrorCode::UnknownType => "UNKNOWN_TYPE",
            ErrorCode::BadImage => "BAD_IMAGE",
            ErrorCode::UnknownModel => "UNKNOWN_MODEL",
            ErrorCode::ResourceExhausted => "RESOURCE_EXHAUSTED",
            ErrorCode::ShuttingDown => "SHUTTING_DOWN",
            ErrorCode::Internal => "INTERNAL",
            ErrorCode::DeadlineExceeded => "DEADLINE_EXCEEDED",
            ErrorCode::Unavailable => "UNAVAILABLE",
        }
    }

    /// Parse the wire encoding; `None` for unknown codes.
    pub fn parse(s: &str) -> Option<ErrorCode> {
        Some(match s {
            "INVALID_FRAME" => ErrorCode::InvalidFrame,
            "UNSUPPORTED_VERSION" => ErrorCode::UnsupportedVersion,
            "UNKNOWN_TYPE" => ErrorCode::UnknownType,
            "BAD_IMAGE" => ErrorCode::BadImage,
            "UNKNOWN_MODEL" => ErrorCode::UnknownModel,
            "RESOURCE_EXHAUSTED" => ErrorCode::ResourceExhausted,
            "SHUTTING_DOWN" => ErrorCode::ShuttingDown,
            "INTERNAL" => ErrorCode::Internal,
            "DEADLINE_EXCEEDED" => ErrorCode::DeadlineExceeded,
            "UNAVAILABLE" => ErrorCode::Unavailable,
            _ => return None,
        })
    }

    /// Whether a client may retry the identical request and reasonably
    /// expect it to succeed (today: `RESOURCE_EXHAUSTED` and
    /// `UNAVAILABLE`).  Execution failures (`INTERNAL`) and spent time
    /// budgets (`DEADLINE_EXCEEDED`) are never retryable.
    pub fn retryable(&self) -> bool {
        matches!(self, ErrorCode::ResourceExhausted | ErrorCode::Unavailable)
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// `infer` — client asks the server to run one image through a model.
#[derive(Clone, Debug, PartialEq)]
pub struct InferFrame {
    /// Client-chosen request id, echoed verbatim in the reply.
    pub id: u64,
    /// Registry model to route to; `None` = the server's default model.
    pub model: Option<String>,
    /// Per-request deadline in milliseconds, measured from server
    /// receipt (v1-additive; `None` = no deadline).  A request whose
    /// deadline expires before its batch launches is answered with
    /// `DEADLINE_EXCEEDED` instead of being served late.
    pub deadline_ms: Option<u64>,
    /// Image dims `[C, H, W]`.
    pub dims: Vec<usize>,
    /// Row-major image data; `data.len()` must equal the dims product.
    pub data: Vec<f32>,
}

/// `infer_ok` — the server's successful answer to an `infer` frame.
#[derive(Clone, Debug, PartialEq)]
pub struct InferOkFrame {
    /// The request id this reply answers.
    pub id: u64,
    /// Model that served the request (`None` = the default backend model).
    pub model: Option<String>,
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// `argmax(logits)`.
    pub predicted: usize,
    /// Time the request spent queued before its batch launched (µs).
    pub queue_us: u64,
    /// Backend execute wall time for the whole batch (µs).
    pub compute_us: u64,
    /// Bucket size of the batch this request rode in (incl. padding).
    pub batch_size: usize,
    /// Live requests in that batch (excl. padding).
    pub batch_occupancy: usize,
    /// Simulated hardware cost of the batch on the modeled accelerator.
    pub hw: HwCost,
}

/// `error` — the receiving side rejected or failed a frame.
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorFrame {
    /// The offending request's id, when the server could still read one.
    pub id: Option<u64>,
    /// Machine-readable category.
    pub code: ErrorCode,
    /// Human-readable detail (not part of the stable protocol surface).
    pub message: String,
}

impl ErrorFrame {
    /// Convenience constructor.
    pub fn new(id: Option<u64>, code: ErrorCode, message: impl Into<String>) -> Self {
        ErrorFrame { id, code, message: message.into() }
    }
}

impl fmt::Display for ErrorFrame {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

/// `models` — the server's answer to `list_models`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModelsFrame {
    /// Registry model names, sorted (empty when no registry is attached).
    pub models: Vec<String>,
    /// Model unnamed requests route to, if any.
    pub default: Option<String>,
}

/// Aggregate network-layer counters reported in the `metrics` frame.
///
/// `*_open`/`inflight` are gauges (current values); everything else is a
/// monotonic counter since server start.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetCounters {
    /// Connections currently open.
    pub connections_open: u64,
    /// Connections accepted since start.
    pub connections_opened: u64,
    /// Connections refused at the connection cap.
    pub connections_rejected: u64,
    /// Frames successfully read off sockets.
    pub frames_received: u64,
    /// Frames written to sockets.
    pub frames_sent: u64,
    /// Infer requests currently admitted and awaiting a response.
    pub inflight: u64,
    /// Idle connections closed by the reaper (no frame within the idle
    /// timeout; v1-additive, absent decodes as 0).
    pub idle_reaped: u64,
    /// Slow-loris connections closed by the reaper (stalled mid-frame
    /// past the frame timeout; v1-additive, absent decodes as 0).
    pub loris_reaped: u64,
    /// Infer frames rejected at the in-flight cap (`RESOURCE_EXHAUSTED`).
    pub overload_rejections: u64,
    /// Frames that failed to decode (connection survived).
    pub protocol_errors: u64,
    /// Infer requests that failed after admission.
    pub requests_failed: u64,
    /// Infer requests answered successfully.
    pub requests_ok: u64,
}

/// `metrics` — serving metrics snapshot: the coordinator's counters and
/// latency percentiles plus the network layer's [`NetCounters`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsFrame {
    /// Execution backend label ("native", "pjrt", ...).
    pub backend: String,
    /// Total requests served by the coordinator.
    pub requests: u64,
    /// Total batches launched.
    pub batches: u64,
    /// Batches that failed (execution error, panic, unknown model).
    pub failed_batches: u64,
    /// Requests dropped because their deadline expired before launch
    /// (v1-additive, absent decodes as 0).
    pub deadline_misses: u64,
    /// Shard workers respawned by the supervisor after dying
    /// (v1-additive, absent decodes as 0).
    pub shard_restarts: u64,
    /// Batches executed by a non-home shard under steal mode, summed
    /// across shards (v1-additive, absent decodes as 0; the canonical
    /// encoding omits it when 0, so a steal-off server's frames stay
    /// byte-identical to pre-elasticity builds).
    pub stolen_batches: u64,
    /// Batches home shards donated to the steal deck that another shard
    /// executed — equals `stolen_batches` in a merged snapshot
    /// (v1-additive, omitted when 0).
    pub donated_batches: u64,
    /// Replica executables lazily compiled on thief shards
    /// (v1-additive, omitted when 0).
    pub replicas_installed: u64,
    /// Replica executables evicted after their model cooled
    /// (v1-additive, omitted when 0).
    pub replicas_evicted: u64,
    /// End-to-end latency percentiles (µs); `None` until data arrives.
    pub p50_us: Option<u64>,
    /// 90th percentile latency (µs).
    pub p90_us: Option<u64>,
    /// 99th percentile latency (µs).
    pub p99_us: Option<u64>,
    /// Per-model request/batch counters, keyed by model name.
    pub per_model: BTreeMap<String, ModelCounters>,
    /// Per-shard counters, indexed by shard id (added in the sharded
    /// coordinator rework; a v1-additive field — sharding is otherwise
    /// invisible on the wire).  Older peers that omit it decode as empty.
    pub shards: Vec<ShardCounters>,
    /// End-to-end latency histogram (µs; v1-additive, absent decodes as
    /// empty).  The `p50_us`/`p90_us`/`p99_us` fields above are derived
    /// from this histogram server-side; the histogram itself lets a
    /// client compute any percentile, or merge snapshots from several
    /// servers, without resampling error.
    pub latency: LogHistogram,
    /// Aggregate per-stage latency histograms — queue-wait, batch-form,
    /// execute, write-back (v1-additive, absent decodes as empty).
    pub stages: StageHistograms,
    /// Per-model per-stage histograms, keyed by model name
    /// (v1-additive, absent decodes as empty).
    pub model_stages: BTreeMap<String, StageHistograms>,
    /// Per-shard per-stage histograms, indexed by shard id
    /// (v1-additive, absent decodes as empty).
    pub shard_stages: Vec<StageHistograms>,
    /// Network-layer counters.
    pub net: NetCounters,
}

/// One request-lifecycle event in a `trace` frame (the wire form of
/// [`crate::obs::TraceEvent`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceEventWire {
    /// Coordinator-assigned request id (0 = shard-level event, e.g. a
    /// worker-kill fault annotation).
    pub id: u64,
    /// Shard that recorded the event.
    pub shard: u64,
    /// What happened (wire form: the stage's snake_case name).
    pub stage: Stage,
    /// Microseconds since the server's trace origin (one clock across
    /// shards and front-ends, so deltas between stages are meaningful;
    /// absolute values are only comparable within one server process).
    pub t_us: u64,
    /// Per-stage auxiliary word (see `docs/WIRE_PROTOCOL.md` for the
    /// per-stage meaning); canonical encoding omits it when 0.
    pub aux: u64,
}

/// `trace` — the server's answer to `get_trace`: recent lifecycle
/// events, time-ascending (v1-additive).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceFrame {
    /// Recorded events, sorted by `t_us` ascending.
    pub events: Vec<TraceEventWire>,
}

/// One protocol frame, either direction.
///
/// Clients send `Infer`, `ListModels`, `GetMetrics`, `GetTrace`, and
/// `Ping`; servers answer with `InferOk`, `Models`, `Metrics`, `Trace`,
/// `Pong`, or `Error`.  A frame arriving on the wrong side is answered
/// with `ErrorCode::InvalidFrame`.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Run one image through a model.
    Infer(InferFrame),
    /// Successful inference reply.
    InferOk(InferOkFrame),
    /// Typed rejection or failure.
    Error(ErrorFrame),
    /// Ask for the server's model names.
    ListModels,
    /// Model names reply.
    Models(ModelsFrame),
    /// Ask for a serving metrics snapshot.
    GetMetrics,
    /// Metrics snapshot reply.
    Metrics(MetricsFrame),
    /// Liveness probe; the server echoes the nonce back in a `Pong`.
    Ping {
        /// Arbitrary client-chosen value echoed in the reply.
        nonce: u64,
    },
    /// Liveness reply carrying the `Ping`'s nonce.
    Pong {
        /// The probed frame's nonce.
        nonce: u64,
    },
    /// Capability negotiation, client → server (v1-additive; optional).
    /// A client that wants pipelined mode sends `hello` as its first
    /// frame; a client that never sends one gets classic serial mode.
    Hello {
        /// Whether the client asks for pipelined (out-of-order,
        /// multiple-in-flight) responses on this connection.
        pipeline: bool,
    },
    /// The server's answer to `Hello`: what this connection actually got.
    HelloOk {
        /// Whether the server granted pipelined mode.  When `false` the
        /// connection stays serial (one in-flight request, responses in
        /// request order) regardless of what the client asked for.
        pipeline: bool,
        /// Per-connection in-flight request cap the server will enforce
        /// (1 when `pipeline` is `false`).
        depth: u64,
    },
    /// Ask for recent request-lifecycle trace events (v1-additive).  A
    /// server without tracing enabled answers with an empty `trace`.
    GetTrace {
        /// Only return events of this request id (`None` = all ids).
        id: Option<u64>,
        /// Return at most this many events, keeping the most recent
        /// (`None` = the server's default cap).
        limit: Option<u64>,
    },
    /// Trace events reply.
    Trace(TraceFrame),
}

impl Frame {
    /// The frame's wire `"type"` tag.
    pub fn type_str(&self) -> &'static str {
        match self {
            Frame::Infer(_) => "infer",
            Frame::InferOk(_) => "infer_ok",
            Frame::Error(_) => "error",
            Frame::ListModels => "list_models",
            Frame::Models(_) => "models",
            Frame::GetMetrics => "get_metrics",
            Frame::Metrics(_) => "metrics",
            Frame::Ping { .. } => "ping",
            Frame::Pong { .. } => "pong",
            Frame::Hello { .. } => "hello",
            Frame::HelloOk { .. } => "hello_ok",
            Frame::GetTrace { .. } => "get_trace",
            Frame::Trace(_) => "trace",
        }
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn uint(n: u64) -> Json {
    Json::Num(n as f64)
}

fn f32_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| num(x as f64)).collect())
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| uint(x as u64)).collect())
}

fn opt_u64_json(v: Option<u64>) -> Json {
    match v {
        Some(n) => uint(n),
        None => Json::Null,
    }
}

/// Base object with the `v` and `type` fields every frame carries.
fn base(type_str: &str) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("v".to_string(), uint(PROTOCOL_VERSION));
    m.insert("type".to_string(), Json::Str(type_str.to_string()));
    m
}

fn put(m: &mut BTreeMap<String, Json>, key: &str, val: Json) {
    m.insert(key.to_string(), val);
}

/// A [`LogHistogram`] as its wire object: `{"buckets": [[index, count],
/// ...], "count": N, "max_us": M, "sum_us": S}` — sparse buckets, so an
/// empty histogram is a handful of bytes and a populated one costs a few
/// bytes per distinct latency octave-slot, never per sample.
fn histogram_json(h: &LogHistogram) -> Json {
    let mut m = BTreeMap::new();
    let buckets = h
        .to_sparse()
        .into_iter()
        .map(|(i, c)| Json::Arr(vec![uint(i as u64), uint(c)]))
        .collect();
    put(&mut m, "buckets", Json::Arr(buckets));
    put(&mut m, "count", uint(h.count()));
    put(&mut m, "max_us", uint(h.max_us()));
    put(&mut m, "sum_us", uint(h.sum_us()));
    Json::Obj(m)
}

/// A [`StageHistograms`] as its wire object, one histogram per stage key
/// (`queue`, `batch_form`, `execute`, `write_back`).
fn stages_json(s: &StageHistograms) -> Json {
    let mut m = BTreeMap::new();
    for (name, h) in s.named() {
        put(&mut m, name, histogram_json(h));
    }
    Json::Obj(m)
}

/// Serialize a frame to its canonical JSON payload (no length prefix).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut m = base(frame.type_str());
    match frame {
        Frame::Infer(f) => {
            put(&mut m, "id", uint(f.id));
            if let Some(model) = &f.model {
                put(&mut m, "model", Json::Str(model.clone()));
            }
            if let Some(deadline_ms) = f.deadline_ms {
                put(&mut m, "deadline_ms", uint(deadline_ms));
            }
            put(&mut m, "dims", usize_arr(&f.dims));
            put(&mut m, "data", f32_arr(&f.data));
        }
        Frame::InferOk(f) => {
            put(&mut m, "id", uint(f.id));
            if let Some(model) = &f.model {
                put(&mut m, "model", Json::Str(model.clone()));
            }
            put(&mut m, "logits", f32_arr(&f.logits));
            put(&mut m, "predicted", uint(f.predicted as u64));
            put(&mut m, "queue_us", uint(f.queue_us));
            put(&mut m, "compute_us", uint(f.compute_us));
            put(&mut m, "batch_size", uint(f.batch_size as u64));
            put(&mut m, "batch_occupancy", uint(f.batch_occupancy as u64));
            let mut hw = BTreeMap::new();
            put(&mut hw, "cycles", uint(f.hw.cycles));
            put(&mut hw, "energy_j", num(f.hw.energy_j));
            put(&mut hw, "accel_time_s", num(f.hw.accel_time_s));
            put(&mut m, "hw", Json::Obj(hw));
        }
        Frame::Error(f) => {
            if let Some(id) = f.id {
                put(&mut m, "id", uint(id));
            }
            put(&mut m, "code", Json::Str(f.code.as_str().to_string()));
            put(&mut m, "message", Json::Str(f.message.clone()));
        }
        Frame::ListModels | Frame::GetMetrics => {}
        Frame::Models(f) => {
            put(
                &mut m,
                "models",
                Json::Arr(f.models.iter().map(|s| Json::Str(s.clone())).collect()),
            );
            if let Some(default) = &f.default {
                put(&mut m, "default", Json::Str(default.clone()));
            }
        }
        Frame::Metrics(f) => {
            put(&mut m, "backend", Json::Str(f.backend.clone()));
            put(&mut m, "requests", uint(f.requests));
            put(&mut m, "batches", uint(f.batches));
            put(&mut m, "failed_batches", uint(f.failed_batches));
            put(&mut m, "deadline_misses", uint(f.deadline_misses));
            put(&mut m, "shard_restarts", uint(f.shard_restarts));
            // steal / replica counters are v1-additive and omitted when
            // 0: a steal-off server's frames stay byte-identical to
            // pre-elasticity builds
            if f.stolen_batches != 0 {
                put(&mut m, "stolen_batches", uint(f.stolen_batches));
            }
            if f.donated_batches != 0 {
                put(&mut m, "donated_batches", uint(f.donated_batches));
            }
            if f.replicas_installed != 0 {
                put(&mut m, "replicas_installed", uint(f.replicas_installed));
            }
            if f.replicas_evicted != 0 {
                put(&mut m, "replicas_evicted", uint(f.replicas_evicted));
            }
            put(&mut m, "p50_us", opt_u64_json(f.p50_us));
            put(&mut m, "p90_us", opt_u64_json(f.p90_us));
            put(&mut m, "p99_us", opt_u64_json(f.p99_us));
            let mut per_model = BTreeMap::new();
            for (name, c) in &f.per_model {
                let mut cm = BTreeMap::new();
                put(&mut cm, "requests", uint(c.requests));
                put(&mut cm, "batches", uint(c.batches));
                put(&mut cm, "failed_batches", uint(c.failed_batches));
                put(&mut cm, "deadline_misses", uint(c.deadline_misses));
                if c.stolen_batches != 0 {
                    put(&mut cm, "stolen_batches", uint(c.stolen_batches));
                }
                per_model.insert(name.clone(), Json::Obj(cm));
            }
            put(&mut m, "per_model", Json::Obj(per_model));
            let shards = f
                .shards
                .iter()
                .map(|s| {
                    let mut sm = BTreeMap::new();
                    put(&mut sm, "requests", uint(s.requests));
                    put(&mut sm, "batches", uint(s.batches));
                    put(&mut sm, "failed_batches", uint(s.failed_batches));
                    put(&mut sm, "deadline_misses", uint(s.deadline_misses));
                    if s.stolen_batches != 0 {
                        put(&mut sm, "stolen_batches", uint(s.stolen_batches));
                    }
                    if s.donated_batches != 0 {
                        put(&mut sm, "donated_batches", uint(s.donated_batches));
                    }
                    Json::Obj(sm)
                })
                .collect();
            put(&mut m, "shards", Json::Arr(shards));
            put(&mut m, "latency", histogram_json(&f.latency));
            put(&mut m, "stages", stages_json(&f.stages));
            let mut model_stages = BTreeMap::new();
            for (name, s) in &f.model_stages {
                model_stages.insert(name.clone(), stages_json(s));
            }
            put(&mut m, "model_stages", Json::Obj(model_stages));
            put(
                &mut m,
                "shard_stages",
                Json::Arr(f.shard_stages.iter().map(stages_json).collect()),
            );
            let n = &f.net;
            let mut nm = BTreeMap::new();
            put(&mut nm, "connections_open", uint(n.connections_open));
            put(&mut nm, "connections_opened", uint(n.connections_opened));
            put(&mut nm, "connections_rejected", uint(n.connections_rejected));
            put(&mut nm, "frames_received", uint(n.frames_received));
            put(&mut nm, "frames_sent", uint(n.frames_sent));
            put(&mut nm, "idle_reaped", uint(n.idle_reaped));
            put(&mut nm, "inflight", uint(n.inflight));
            put(&mut nm, "loris_reaped", uint(n.loris_reaped));
            put(&mut nm, "overload_rejections", uint(n.overload_rejections));
            put(&mut nm, "protocol_errors", uint(n.protocol_errors));
            put(&mut nm, "requests_failed", uint(n.requests_failed));
            put(&mut nm, "requests_ok", uint(n.requests_ok));
            put(&mut m, "net", Json::Obj(nm));
        }
        Frame::Ping { nonce } | Frame::Pong { nonce } => {
            put(&mut m, "nonce", uint(*nonce));
        }
        Frame::Hello { pipeline } => {
            // canonical form omits the default: a plain hello asks for
            // nothing and exists only to probe what the server grants
            if *pipeline {
                put(&mut m, "pipeline", Json::Bool(true));
            }
        }
        Frame::HelloOk { pipeline, depth } => {
            put(&mut m, "pipeline", Json::Bool(*pipeline));
            put(&mut m, "depth", uint(*depth));
        }
        Frame::GetTrace { id, limit } => {
            if let Some(id) = id {
                put(&mut m, "id", uint(*id));
            }
            if let Some(limit) = limit {
                put(&mut m, "limit", uint(*limit));
            }
        }
        Frame::Trace(f) => {
            let events = f
                .events
                .iter()
                .map(|e| {
                    let mut em = BTreeMap::new();
                    put(&mut em, "id", uint(e.id));
                    put(&mut em, "shard", uint(e.shard));
                    put(&mut em, "stage", Json::Str(e.stage.as_str().to_string()));
                    put(&mut em, "t_us", uint(e.t_us));
                    // canonical form omits the default aux word
                    if e.aux != 0 {
                        put(&mut em, "aux", uint(e.aux));
                    }
                    Json::Obj(em)
                })
                .collect();
            put(&mut m, "events", Json::Arr(events));
        }
    }
    Json::Obj(m).to_string().into_bytes()
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

type FieldResult<T> = Result<T, String>;

fn need<'a>(obj: &'a BTreeMap<String, Json>, key: &str) -> FieldResult<&'a Json> {
    obj.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

fn need_u64(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<u64> {
    as_u64(need(obj, key)?).ok_or_else(|| format!("field '{key}' must be a non-negative integer"))
}

fn as_u64(v: &Json) -> Option<u64> {
    let n = v.as_f64()?;
    if n >= 0.0 && n.fract() == 0.0 && n <= (1u64 << 53) as f64 {
        Some(n as u64)
    } else {
        None
    }
}

fn opt_u64(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<Option<u64>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => as_u64(v)
            .map(Some)
            .ok_or_else(|| format!("field '{key}' must be a non-negative integer or null")),
    }
}

fn need_usize(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<usize> {
    Ok(need_u64(obj, key)? as usize)
}

fn need_f64(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<f64> {
    need(obj, key)?
        .as_f64()
        .ok_or_else(|| format!("field '{key}' must be a number"))
}

fn need_str(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<String> {
    Ok(need(obj, key)?
        .as_str()
        .ok_or_else(|| format!("field '{key}' must be a string"))?
        .to_string())
}

fn need_bool(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<bool> {
    match need(obj, key)? {
        Json::Bool(b) => Ok(*b),
        _ => Err(format!("field '{key}' must be a boolean")),
    }
}

fn opt_bool(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<Option<bool>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(format!("field '{key}' must be a boolean or null")),
    }
}

fn opt_str(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<Option<String>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(
            v.as_str().ok_or_else(|| format!("field '{key}' must be a string"))?.to_string(),
        )),
    }
}

fn need_f32_arr(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<Vec<f32>> {
    let items = need(obj, key)?
        .as_arr()
        .ok_or_else(|| format!("field '{key}' must be an array"))?;
    items
        .iter()
        .map(|v| v.as_f64().map(|n| n as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| format!("field '{key}' must contain only numbers"))
}

fn need_usize_arr(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<Vec<usize>> {
    let items = need(obj, key)?
        .as_arr()
        .ok_or_else(|| format!("field '{key}' must be an array"))?;
    items
        .iter()
        .map(|v| as_u64(v).map(|n| n as usize))
        .collect::<Option<Vec<usize>>>()
        .ok_or_else(|| format!("field '{key}' must contain only non-negative integers"))
}

fn need_str_arr(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<Vec<String>> {
    let items = need(obj, key)?
        .as_arr()
        .ok_or_else(|| format!("field '{key}' must be an array"))?;
    items
        .iter()
        .map(|v| v.as_str().map(str::to_string))
        .collect::<Option<Vec<String>>>()
        .ok_or_else(|| format!("field '{key}' must contain only strings"))
}

/// Decode a histogram wire object (see [`histogram_json`]).  The
/// redundant `count` field is validated against the bucket sum so a
/// corrupted or hand-edited frame cannot smuggle in an inconsistent
/// histogram.
fn decode_histogram(v: &Json, what: &str) -> FieldResult<LogHistogram> {
    let obj = v.as_obj().ok_or_else(|| format!("{what} must be an object"))?;
    let items = need(obj, "buckets")?
        .as_arr()
        .ok_or_else(|| format!("{what}: field 'buckets' must be an array"))?;
    let mut buckets = Vec::with_capacity(items.len());
    for item in items {
        let pair = item
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| format!("{what}: each bucket must be an [index, count] pair"))?;
        let idx = as_u64(&pair[0])
            .ok_or_else(|| format!("{what}: bucket index must be a non-negative integer"))?;
        let count = as_u64(&pair[1])
            .ok_or_else(|| format!("{what}: bucket count must be a non-negative integer"))?;
        buckets.push((idx as usize, count));
    }
    let h = LogHistogram::from_sparse(need_u64(obj, "sum_us")?, need_u64(obj, "max_us")?, &buckets);
    if h.count() != need_u64(obj, "count")? {
        return Err(format!("{what}: 'count' does not match the bucket sum"));
    }
    Ok(h)
}

/// Decode a per-stage histogram wire object (see [`stages_json`]).
fn decode_stages(v: &Json, what: &str) -> FieldResult<StageHistograms> {
    let obj = v.as_obj().ok_or_else(|| format!("{what} must be an object"))?;
    Ok(StageHistograms {
        queue: decode_histogram(need(obj, "queue")?, &format!("{what}.queue"))?,
        batch_form: decode_histogram(need(obj, "batch_form")?, &format!("{what}.batch_form"))?,
        execute: decode_histogram(need(obj, "execute")?, &format!("{what}.execute"))?,
        write_back: decode_histogram(need(obj, "write_back")?, &format!("{what}.write_back"))?,
    })
}

/// Decode an *optional* per-stage histogram field: absent (an older
/// peer) decodes as empty, the v1-additive convention.
fn opt_stages(obj: &BTreeMap<String, Json>, key: &str) -> FieldResult<StageHistograms> {
    match obj.get(key) {
        None => Ok(StageHistograms::default()),
        Some(v) => decode_stages(v, &format!("field '{key}'")),
    }
}

/// Parse a canonical-JSON payload into a [`Frame`].
///
/// On failure, the returned [`ErrorFrame`] carries the appropriate
/// [`ErrorCode`] (and the request's `id` when one could still be read),
/// ready to be sent back as a typed `error` frame — a decode failure
/// never requires dropping the connection, because framing is intact.
pub fn decode(payload: &[u8]) -> Result<Frame, ErrorFrame> {
    let bad = |code: ErrorCode, msg: String| ErrorFrame::new(None, code, msg);
    let text = std::str::from_utf8(payload)
        .map_err(|e| bad(ErrorCode::InvalidFrame, format!("payload is not UTF-8: {e}")))?;
    let value = json::parse(text)
        .map_err(|e| bad(ErrorCode::InvalidFrame, format!("payload is not JSON: {e}")))?;
    let obj = value
        .as_obj()
        .ok_or_else(|| bad(ErrorCode::InvalidFrame, "payload is not a JSON object".into()))?;
    // best-effort id for error attribution, before any validation
    let id = obj.get("id").and_then(as_u64);
    let fail = |code: ErrorCode, msg: String| ErrorFrame::new(id, code, msg);

    let version = need_u64(obj, "v").map_err(|m| fail(ErrorCode::InvalidFrame, m))?;
    if version != PROTOCOL_VERSION {
        return Err(fail(
            ErrorCode::UnsupportedVersion,
            format!("protocol version {version} (this build speaks {PROTOCOL_VERSION})"),
        ));
    }
    let type_str = need_str(obj, "type").map_err(|m| fail(ErrorCode::InvalidFrame, m))?;
    let invalid = |m: String| fail(ErrorCode::InvalidFrame, m);
    match type_str.as_str() {
        "infer" => Ok(Frame::Infer(InferFrame {
            id: need_u64(obj, "id").map_err(invalid)?,
            model: opt_str(obj, "model").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
            deadline_ms: opt_u64(obj, "deadline_ms")
                .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
            dims: need_usize_arr(obj, "dims").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
            data: need_f32_arr(obj, "data").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
        })),
        "infer_ok" => {
            let hw_obj = need(obj, "hw")
                .and_then(|v| v.as_obj().ok_or_else(|| "field 'hw' must be an object".into()))
                .map_err(|m| fail(ErrorCode::InvalidFrame, m))?;
            let efail = |m: String| fail(ErrorCode::InvalidFrame, m);
            Ok(Frame::InferOk(InferOkFrame {
                id: need_u64(obj, "id").map_err(efail)?,
                model: opt_str(obj, "model").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                logits: need_f32_arr(obj, "logits").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                predicted: need_usize(obj, "predicted")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                queue_us: need_u64(obj, "queue_us").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                compute_us: need_u64(obj, "compute_us")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                batch_size: need_usize(obj, "batch_size")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                batch_occupancy: need_usize(obj, "batch_occupancy")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                hw: HwCost {
                    cycles: need_u64(hw_obj, "cycles")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    energy_j: need_f64(hw_obj, "energy_j")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    accel_time_s: need_f64(hw_obj, "accel_time_s")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                },
            }))
        }
        "error" => {
            let code_str = need_str(obj, "code").map_err(|m| fail(ErrorCode::InvalidFrame, m))?;
            let code = ErrorCode::parse(&code_str).ok_or_else(|| {
                fail(ErrorCode::InvalidFrame, format!("unknown error code '{code_str}'"))
            })?;
            Ok(Frame::Error(ErrorFrame {
                id,
                code,
                message: need_str(obj, "message").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
            }))
        }
        "list_models" => Ok(Frame::ListModels),
        "models" => Ok(Frame::Models(ModelsFrame {
            models: need_str_arr(obj, "models").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
            default: opt_str(obj, "default").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
        })),
        "get_metrics" => Ok(Frame::GetMetrics),
        "metrics" => {
            let mfail = |m: String| fail(ErrorCode::InvalidFrame, m);
            let per_model_obj = need(obj, "per_model")
                .and_then(|v| {
                    v.as_obj().ok_or_else(|| "field 'per_model' must be an object".into())
                })
                .map_err(mfail)?;
            let mut per_model = BTreeMap::new();
            for (name, counters) in per_model_obj {
                let c = counters
                    .as_obj()
                    .ok_or_else(|| fail(ErrorCode::InvalidFrame, format!("model '{name}'")))?;
                per_model.insert(
                    name.clone(),
                    ModelCounters {
                        requests: need_u64(c, "requests")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                        batches: need_u64(c, "batches")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                        failed_batches: need_u64(c, "failed_batches")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                        deadline_misses: opt_u64(c, "deadline_misses")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                            .unwrap_or(0),
                        stolen_batches: opt_u64(c, "stolen_batches")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                            .unwrap_or(0),
                    },
                );
            }
            // additive v1 field: absent (an older peer) decodes as empty
            let mut shards = Vec::new();
            if let Some(shards_val) = obj.get("shards") {
                let items = shards_val.as_arr().ok_or_else(|| {
                    fail(ErrorCode::InvalidFrame, "field 'shards' must be an array".into())
                })?;
                for (i, item) in items.iter().enumerate() {
                    let s = item.as_obj().ok_or_else(|| {
                        fail(ErrorCode::InvalidFrame, format!("shard entry {i} must be an object"))
                    })?;
                    shards.push(ShardCounters {
                        requests: need_u64(s, "requests")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                        batches: need_u64(s, "batches")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                        failed_batches: need_u64(s, "failed_batches")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                        deadline_misses: opt_u64(s, "deadline_misses")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                            .unwrap_or(0),
                        stolen_batches: opt_u64(s, "stolen_batches")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                            .unwrap_or(0),
                        donated_batches: opt_u64(s, "donated_batches")
                            .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                            .unwrap_or(0),
                    });
                }
            }
            // additive v1 fields: absent histograms decode as empty
            let latency = match obj.get("latency") {
                None => LogHistogram::default(),
                Some(v) => decode_histogram(v, "field 'latency'").map_err(mfail)?,
            };
            let stages = opt_stages(obj, "stages").map_err(mfail)?;
            let mut model_stages = BTreeMap::new();
            if let Some(ms_val) = obj.get("model_stages") {
                let ms_obj = ms_val.as_obj().ok_or_else(|| {
                    fail(ErrorCode::InvalidFrame, "field 'model_stages' must be an object".into())
                })?;
                for (name, v) in ms_obj {
                    let s = decode_stages(v, &format!("model_stages['{name}']")).map_err(mfail)?;
                    model_stages.insert(name.clone(), s);
                }
            }
            let mut shard_stages = Vec::new();
            if let Some(ss_val) = obj.get("shard_stages") {
                let items = ss_val.as_arr().ok_or_else(|| {
                    fail(ErrorCode::InvalidFrame, "field 'shard_stages' must be an array".into())
                })?;
                for (i, item) in items.iter().enumerate() {
                    shard_stages
                        .push(decode_stages(item, &format!("shard_stages[{i}]")).map_err(mfail)?);
                }
            }
            let net_obj = need(obj, "net")
                .and_then(|v| v.as_obj().ok_or_else(|| "field 'net' must be an object".into()))
                .map_err(|m| fail(ErrorCode::InvalidFrame, m))?;
            let nfail = |m: String| fail(ErrorCode::InvalidFrame, m);
            Ok(Frame::Metrics(MetricsFrame {
                backend: need_str(obj, "backend").map_err(nfail)?,
                requests: need_u64(obj, "requests").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                batches: need_u64(obj, "batches").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                failed_batches: need_u64(obj, "failed_batches")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                deadline_misses: opt_u64(obj, "deadline_misses")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                    .unwrap_or(0),
                shard_restarts: opt_u64(obj, "shard_restarts")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                    .unwrap_or(0),
                stolen_batches: opt_u64(obj, "stolen_batches")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                    .unwrap_or(0),
                donated_batches: opt_u64(obj, "donated_batches")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                    .unwrap_or(0),
                replicas_installed: opt_u64(obj, "replicas_installed")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                    .unwrap_or(0),
                replicas_evicted: opt_u64(obj, "replicas_evicted")
                    .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                    .unwrap_or(0),
                p50_us: opt_u64(obj, "p50_us").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                p90_us: opt_u64(obj, "p90_us").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                p99_us: opt_u64(obj, "p99_us").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                per_model,
                shards,
                latency,
                stages,
                model_stages,
                shard_stages,
                net: NetCounters {
                    connections_open: need_u64(net_obj, "connections_open")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    connections_opened: need_u64(net_obj, "connections_opened")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    connections_rejected: need_u64(net_obj, "connections_rejected")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    frames_received: need_u64(net_obj, "frames_received")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    frames_sent: need_u64(net_obj, "frames_sent")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    idle_reaped: opt_u64(net_obj, "idle_reaped")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                        .unwrap_or(0),
                    inflight: need_u64(net_obj, "inflight")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    loris_reaped: opt_u64(net_obj, "loris_reaped")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                        .unwrap_or(0),
                    overload_rejections: need_u64(net_obj, "overload_rejections")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    protocol_errors: need_u64(net_obj, "protocol_errors")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    requests_failed: need_u64(net_obj, "requests_failed")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    requests_ok: need_u64(net_obj, "requests_ok")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                },
            }))
        }
        "ping" => Ok(Frame::Ping {
            nonce: need_u64(obj, "nonce").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
        }),
        "pong" => Ok(Frame::Pong {
            nonce: need_u64(obj, "nonce").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
        }),
        "hello" => Ok(Frame::Hello {
            pipeline: opt_bool(obj, "pipeline")
                .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                .unwrap_or(false),
        }),
        "hello_ok" => Ok(Frame::HelloOk {
            pipeline: need_bool(obj, "pipeline").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
            depth: need_u64(obj, "depth").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
        }),
        "get_trace" => Ok(Frame::GetTrace {
            id,
            limit: opt_u64(obj, "limit").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
        }),
        "trace" => {
            let items = need(obj, "events")
                .and_then(|v| v.as_arr().ok_or_else(|| "field 'events' must be an array".into()))
                .map_err(|m| fail(ErrorCode::InvalidFrame, m))?;
            let mut events = Vec::with_capacity(items.len());
            for (i, item) in items.iter().enumerate() {
                let e = item.as_obj().ok_or_else(|| {
                    fail(ErrorCode::InvalidFrame, format!("event {i} must be an object"))
                })?;
                let efail = |m: String| fail(ErrorCode::InvalidFrame, m);
                let stage_str = need_str(e, "stage").map_err(efail)?;
                let stage = Stage::parse(&stage_str).ok_or_else(|| {
                    fail(ErrorCode::InvalidFrame, format!("event {i}: unknown stage '{stage_str}'"))
                })?;
                events.push(TraceEventWire {
                    id: need_u64(e, "id").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    shard: need_u64(e, "shard").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    stage,
                    t_us: need_u64(e, "t_us").map_err(|m| fail(ErrorCode::InvalidFrame, m))?,
                    aux: opt_u64(e, "aux")
                        .map_err(|m| fail(ErrorCode::InvalidFrame, m))?
                        .unwrap_or(0),
                });
            }
            Ok(Frame::Trace(TraceFrame { events }))
        }
        other => Err(fail(ErrorCode::UnknownType, format!("unknown frame type '{other}'"))),
    }
}

// ---------------------------------------------------------------------------
// Framed transport
// ---------------------------------------------------------------------------

/// Result of one [`read_frame`] call.
#[derive(Debug)]
pub enum ReadOutcome {
    /// The peer closed the connection cleanly at a frame boundary.
    Eof,
    /// A well-formed frame.
    Frame(Frame),
    /// The payload was well-framed but failed to decode; the connection
    /// can continue (send the [`ErrorFrame`] back and keep reading).
    Bad(ErrorFrame),
}

/// Write one length-prefixed frame and flush.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    let payload = encode(frame);
    let len = u32::try_from(payload.len()).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, "frame payload exceeds u32 length")
    })?;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(&payload)?;
    w.flush()
}

/// Blocking read of one length-prefixed frame.
///
/// Clean EOF before the first header byte is [`ReadOutcome::Eof`]; EOF
/// mid-frame is an `UnexpectedEof` error.  A declared payload length
/// above `max_frame_bytes` is an `InvalidData` error — framing can no
/// longer be trusted, so the caller must drop the connection.
pub fn read_frame<R: Read>(r: &mut R, max_frame_bytes: usize) -> std::io::Result<ReadOutcome> {
    let mut header = [0u8; 4];
    match r.read(&mut header)? {
        0 => return Ok(ReadOutcome::Eof),
        n => r.read_exact(&mut header[n..])?,
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > max_frame_bytes {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {max_frame_bytes}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(match decode(&payload) {
        Ok(frame) => ReadOutcome::Frame(frame),
        Err(e) => ReadOutcome::Bad(e),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn hist(values: &[u64]) -> LogHistogram {
        let mut h = LogHistogram::new();
        for &v in values {
            h.record(v);
        }
        h
    }

    fn sample_stages(scale: u64) -> StageHistograms {
        StageHistograms {
            queue: hist(&[140 * scale, 300 * scale]),
            batch_form: hist(&[12 * scale]),
            execute: hist(&[112 * scale, 130 * scale]),
            write_back: hist(&[9 * scale]),
        }
    }

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Infer(InferFrame {
                id: 7,
                model: Some("digits-b8".into()),
                deadline_ms: Some(250),
                dims: vec![1, 2, 2],
                data: vec![0.0, 0.5, -1.25, 3.0],
            }),
            Frame::Infer(InferFrame {
                id: 8,
                model: None,
                deadline_ms: None,
                dims: vec![1, 1, 1],
                data: vec![1.0],
            }),
            Frame::InferOk(InferOkFrame {
                id: 7,
                model: Some("digits-b8".into()),
                logits: vec![0.125, -2.5],
                predicted: 0,
                queue_us: 140,
                compute_us: 112,
                batch_size: 8,
                batch_occupancy: 5,
                hw: HwCost { cycles: 9200, energy_j: 0.0000011, accel_time_s: 0.0000092 },
            }),
            Frame::Error(ErrorFrame::new(
                Some(9),
                ErrorCode::ResourceExhausted,
                "server at max in-flight requests (256)",
            )),
            Frame::Error(ErrorFrame::new(None, ErrorCode::InvalidFrame, "payload is not JSON")),
            Frame::ListModels,
            Frame::Models(ModelsFrame {
                models: vec!["digits-b16".into(), "digits-b8".into()],
                default: Some("digits-b16".into()),
            }),
            Frame::GetMetrics,
            Frame::Metrics(MetricsFrame {
                backend: "native".into(),
                requests: 38,
                batches: 12,
                failed_batches: 0,
                deadline_misses: 2,
                shard_restarts: 1,
                stolen_batches: 3,
                donated_batches: 3,
                replicas_installed: 1,
                replicas_evicted: 1,
                p50_us: Some(950),
                p90_us: Some(1800),
                p99_us: None,
                per_model: [(
                    "digits-b8".to_string(),
                    ModelCounters {
                        requests: 20,
                        batches: 6,
                        failed_batches: 0,
                        deadline_misses: 2,
                        stolen_batches: 3,
                    },
                )]
                .into_iter()
                .collect(),
                shards: vec![
                    ShardCounters {
                        requests: 20,
                        batches: 6,
                        failed_batches: 0,
                        deadline_misses: 2,
                        stolen_batches: 0,
                        donated_batches: 3,
                    },
                    ShardCounters {
                        requests: 18,
                        batches: 6,
                        failed_batches: 0,
                        deadline_misses: 0,
                        stolen_batches: 3,
                        donated_batches: 0,
                    },
                ],
                latency: hist(&[950, 1800, 120]),
                stages: sample_stages(2),
                model_stages: [("digits-b8".to_string(), sample_stages(1))].into_iter().collect(),
                shard_stages: vec![sample_stages(1), sample_stages(3)],
                net: NetCounters {
                    connections_open: 1,
                    connections_opened: 3,
                    connections_rejected: 0,
                    frames_received: 40,
                    frames_sent: 40,
                    idle_reaped: 1,
                    inflight: 1,
                    loris_reaped: 1,
                    overload_rejections: 2,
                    protocol_errors: 0,
                    requests_failed: 0,
                    requests_ok: 38,
                },
            }),
            Frame::Ping { nonce: 99 },
            Frame::Pong { nonce: 99 },
            Frame::Hello { pipeline: true },
            Frame::Hello { pipeline: false },
            Frame::HelloOk { pipeline: true, depth: 32 },
            Frame::HelloOk { pipeline: false, depth: 1 },
            Frame::GetTrace { id: None, limit: None },
            Frame::GetTrace { id: Some(7), limit: Some(512) },
            Frame::Trace(TraceFrame::default()),
            Frame::Trace(TraceFrame {
                events: vec![
                    TraceEventWire { id: 7, shard: 0, stage: Stage::Accepted, t_us: 10, aux: 0 },
                    TraceEventWire { id: 7, shard: 0, stage: Stage::Enqueued, t_us: 25, aux: 3 },
                    TraceEventWire {
                        id: 7,
                        shard: 0,
                        stage: Stage::Executed,
                        t_us: 930,
                        aux: 640,
                    },
                ],
            }),
        ]
    }

    #[test]
    fn every_frame_round_trips() {
        for frame in sample_frames() {
            let bytes = encode(&frame);
            let back = decode(&bytes).unwrap_or_else(|e| panic!("{}: {e}", frame.type_str()));
            assert_eq!(back, frame, "{}", frame.type_str());
            // canonical: decode → encode reproduces the identical bytes
            assert_eq!(encode(&back), bytes, "{}", frame.type_str());
        }
    }

    #[test]
    fn encoding_is_canonical_text() {
        let frame = Frame::Infer(InferFrame {
            id: 1,
            model: None,
            deadline_ms: None,
            dims: vec![1, 2, 2],
            data: vec![0.0, 0.5, 1.0, -2.0],
        });
        assert_eq!(
            String::from_utf8(encode(&frame)).unwrap(),
            r#"{"data":[0,0.5,1,-2],"dims":[1,2,2],"id":1,"type":"infer","v":1}"#
        );
        assert_eq!(
            String::from_utf8(encode(&Frame::Ping { nonce: 7 })).unwrap(),
            r#"{"nonce":7,"type":"ping","v":1}"#
        );
    }

    #[test]
    fn hello_negotiation_is_v1_additive() {
        // the canonical non-pipelined hello omits the default field, so
        // old decoders that never learned 'pipeline' are not the only
        // compatibility story — new decoders accept its absence too
        assert_eq!(
            String::from_utf8(encode(&Frame::Hello { pipeline: false })).unwrap(),
            r#"{"type":"hello","v":1}"#
        );
        assert_eq!(
            String::from_utf8(encode(&Frame::Hello { pipeline: true })).unwrap(),
            r#"{"pipeline":true,"type":"hello","v":1}"#
        );
        assert_eq!(
            String::from_utf8(encode(&Frame::HelloOk { pipeline: true, depth: 32 })).unwrap(),
            r#"{"depth":32,"pipeline":true,"type":"hello_ok","v":1}"#
        );
        match decode(br#"{"type":"hello","v":1}"#).unwrap() {
            Frame::Hello { pipeline } => assert!(!pipeline),
            other => panic!("expected hello, got {other:?}"),
        }
        let e = decode(br#"{"pipeline":1,"type":"hello","v":1}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidFrame);
        let e = decode(br#"{"pipeline":true,"type":"hello_ok","v":1}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidFrame); // missing depth
    }

    #[test]
    fn metrics_without_shards_decodes_as_empty() {
        // a pre-sharding peer omits the additive 'shards' field; the frame
        // must still decode (v1 compatibility), with no shard entries
        let payload = br#"{"backend":"native","batches":1,"failed_batches":0,"net":{"connections_open":0,"connections_opened":0,"connections_rejected":0,"frames_received":0,"frames_sent":0,"inflight":0,"overload_rejections":0,"protocol_errors":0,"requests_failed":0,"requests_ok":0},"p50_us":null,"p90_us":null,"p99_us":null,"per_model":{},"requests":1,"type":"metrics","v":1}"#;
        match decode(payload).unwrap() {
            Frame::Metrics(m) => {
                assert_eq!(m.requests, 1);
                assert!(m.shards.is_empty());
            }
            other => panic!("expected metrics, got {other:?}"),
        }
    }

    #[test]
    fn steal_counters_are_v1_additive_and_omitted_when_zero() {
        // a steal-off (or pre-elasticity) server reports all-zero steal
        // counters: the canonical encoding omits every one of them, so
        // its frames are byte-identical to pre-elasticity builds
        let quiet = ModelCounters { requests: 1, batches: 1, ..ModelCounters::default() };
        let frame = Frame::Metrics(MetricsFrame {
            backend: "native".into(),
            requests: 1,
            batches: 1,
            per_model: [("m".to_string(), quiet)].into_iter().collect(),
            shards: vec![ShardCounters { requests: 1, batches: 1, ..ShardCounters::default() }],
            ..MetricsFrame::default()
        });
        let text = String::from_utf8(encode(&frame)).unwrap();
        let steal_fields =
            ["stolen_batches", "donated_batches", "replicas_installed", "replicas_evicted"];
        for field in steal_fields {
            assert!(!text.contains(field), "zero '{field}' must be omitted: {text}");
        }
        // absent on decode (an older peer) means zero everywhere
        match decode(text.as_bytes()).unwrap() {
            Frame::Metrics(m) => {
                assert_eq!(m.stolen_batches, 0);
                assert_eq!(m.donated_batches, 0);
                assert_eq!(m.replicas_installed, 0);
                assert_eq!(m.replicas_evicted, 0);
                assert_eq!(m.per_model["m"].stolen_batches, 0);
                assert_eq!(m.shards[0].stolen_batches, 0);
                assert_eq!(m.shards[0].donated_batches, 0);
            }
            other => panic!("expected metrics, got {other:?}"),
        }
    }

    #[test]
    fn trace_frames_are_canonical() {
        assert_eq!(
            String::from_utf8(encode(&Frame::GetTrace { id: None, limit: None })).unwrap(),
            r#"{"type":"get_trace","v":1}"#
        );
        assert_eq!(
            String::from_utf8(encode(&Frame::GetTrace { id: Some(7), limit: Some(512) })).unwrap(),
            r#"{"id":7,"limit":512,"type":"get_trace","v":1}"#
        );
        // aux = 0 is omitted from the canonical event encoding
        let frame = Frame::Trace(TraceFrame {
            events: vec![TraceEventWire {
                id: 7,
                shard: 1,
                stage: Stage::Accepted,
                t_us: 10,
                aux: 0,
            }],
        });
        assert_eq!(
            String::from_utf8(encode(&frame)).unwrap(),
            r#"{"events":[{"id":7,"shard":1,"stage":"accepted","t_us":10}],"type":"trace","v":1}"#
        );
        // an unknown stage name is a typed decode error, not a panic
        let bad = br#"{"events":[{"id":1,"shard":0,"stage":"warp","t_us":1}],"type":"trace","v":1}"#;
        assert_eq!(decode(bad).unwrap_err().code, ErrorCode::InvalidFrame);
    }

    #[test]
    fn histograms_in_metrics_are_v1_additive() {
        // a pre-observability peer omits every histogram field: all of
        // them decode as empty
        let payload = br#"{"backend":"native","batches":1,"failed_batches":0,"net":{"connections_open":0,"connections_opened":0,"connections_rejected":0,"frames_received":0,"frames_sent":0,"inflight":0,"overload_rejections":0,"protocol_errors":0,"requests_failed":0,"requests_ok":0},"p50_us":null,"p90_us":null,"p99_us":null,"per_model":{},"requests":1,"shards":[],"type":"metrics","v":1}"#;
        match decode(payload).unwrap() {
            Frame::Metrics(m) => {
                assert!(m.latency.is_empty());
                assert!(m.stages.is_empty());
                assert!(m.model_stages.is_empty());
                assert!(m.shard_stages.is_empty());
            }
            other => panic!("expected metrics, got {other:?}"),
        }
        // a histogram whose 'count' disagrees with its buckets is rejected
        let mut h = hist(&[100, 200]);
        let frame = MetricsFrame { latency: h.clone(), ..MetricsFrame::default() };
        let good = encode(&Frame::Metrics(frame));
        let text = String::from_utf8(good.clone()).unwrap();
        assert!(decode(&good).is_ok());
        let tampered = text.replace(r#""count":2"#, r#""count":3"#);
        assert_eq!(decode(tampered.as_bytes()).unwrap_err().code, ErrorCode::InvalidFrame);
        // round trip preserves exact percentile structure
        h.merge(&hist(&[50]));
        let back = LogHistogram::from_sparse(h.sum_us(), h.max_us(), &h.to_sparse());
        assert_eq!(back, h);
    }

    #[test]
    fn rejects_wrong_version() {
        let e = decode(br#"{"type":"ping","nonce":1,"v":2}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::UnsupportedVersion);
        let e = decode(br#"{"type":"ping","nonce":1}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidFrame);
    }

    #[test]
    fn rejects_unknown_type_and_garbage() {
        let e = decode(br#"{"type":"teleport","v":1}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::UnknownType);
        let e = decode(b"not json at all").unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidFrame);
        let e = decode(br#"[1,2,3]"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidFrame);
        let e = decode(&[0xff, 0xfe]).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidFrame);
    }

    #[test]
    fn decode_errors_carry_the_request_id() {
        // id readable but dims missing: the error must name the request
        let e = decode(br#"{"id":42,"type":"infer","v":1,"data":[]}"#).unwrap_err();
        assert_eq!(e.id, Some(42));
        assert_eq!(e.code, ErrorCode::InvalidFrame);
    }

    #[test]
    fn rejects_non_integer_ids() {
        let e = decode(br#"{"id":1.5,"type":"ping","nonce":1,"v":1}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidFrame);
        let e = decode(br#"{"data":[],"dims":[],"id":-3,"type":"infer","v":1}"#).unwrap_err();
        assert_eq!(e.code, ErrorCode::InvalidFrame);
    }

    #[test]
    fn error_codes_round_trip() {
        for code in [
            ErrorCode::InvalidFrame,
            ErrorCode::UnsupportedVersion,
            ErrorCode::UnknownType,
            ErrorCode::BadImage,
            ErrorCode::UnknownModel,
            ErrorCode::ResourceExhausted,
            ErrorCode::ShuttingDown,
            ErrorCode::DeadlineExceeded,
            ErrorCode::Unavailable,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
        }
        assert_eq!(ErrorCode::parse("NOPE"), None);
        assert!(ErrorCode::ResourceExhausted.retryable());
        assert!(ErrorCode::Unavailable.retryable());
        assert!(!ErrorCode::DeadlineExceeded.retryable());
        assert!(!ErrorCode::Internal.retryable());
    }

    #[test]
    fn deadline_and_fault_counters_are_v1_additive() {
        // an older peer omits deadline_ms: decodes as None, and the
        // canonical re-encode also omits it
        let payload = br#"{"data":[1],"dims":[1,1,1],"id":3,"type":"infer","v":1}"#;
        match decode(payload).unwrap() {
            Frame::Infer(f) => {
                assert_eq!(f.deadline_ms, None);
                assert_eq!(encode(&Frame::Infer(f)), payload.to_vec());
            }
            other => panic!("expected infer, got {other:?}"),
        }
        // a pre-fault-tolerance metrics frame omits every new counter;
        // they all decode as zero
        let payload = br#"{"backend":"native","batches":1,"failed_batches":0,"net":{"connections_open":0,"connections_opened":0,"connections_rejected":0,"frames_received":0,"frames_sent":0,"inflight":0,"overload_rejections":0,"protocol_errors":0,"requests_failed":0,"requests_ok":0},"p50_us":null,"p90_us":null,"p99_us":null,"per_model":{"m":{"batches":1,"failed_batches":0,"requests":1}},"requests":1,"shards":[{"batches":1,"failed_batches":0,"requests":1}],"type":"metrics","v":1}"#;
        match decode(payload).unwrap() {
            Frame::Metrics(m) => {
                assert_eq!(m.deadline_misses, 0);
                assert_eq!(m.shard_restarts, 0);
                assert_eq!(m.net.idle_reaped, 0);
                assert_eq!(m.net.loris_reaped, 0);
                assert_eq!(m.per_model["m"].deadline_misses, 0);
                assert_eq!(m.shards[0].deadline_misses, 0);
            }
            other => panic!("expected metrics, got {other:?}"),
        }
    }

    #[test]
    fn framed_transport_round_trips() {
        let mut buf = Vec::new();
        for frame in sample_frames() {
            write_frame(&mut buf, &frame).unwrap();
        }
        let mut cursor = Cursor::new(buf);
        for want in sample_frames() {
            match read_frame(&mut cursor, DEFAULT_MAX_FRAME_BYTES).unwrap() {
                ReadOutcome::Frame(got) => assert_eq!(got, want),
                other => panic!("expected {}, got {other:?}", want.type_str()),
            }
        }
        assert!(matches!(
            read_frame(&mut cursor, DEFAULT_MAX_FRAME_BYTES).unwrap(),
            ReadOutcome::Eof
        ));
    }

    #[test]
    fn oversized_frame_is_an_io_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ListModels).unwrap();
        let mut cursor = Cursor::new(buf);
        let err = read_frame(&mut cursor, 4).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn truncated_frame_is_unexpected_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Ping { nonce: 1 }).unwrap();
        buf.truncate(buf.len() - 3);
        let mut cursor = Cursor::new(buf);
        let err = read_frame(&mut cursor, DEFAULT_MAX_FRAME_BYTES).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn bad_payload_keeps_the_connection_usable() {
        // a well-framed but undecodable payload, then a good frame: the
        // reader surfaces Bad and then keeps going
        let mut buf = Vec::new();
        let junk = br#"{"type":"teleport","v":1}"#;
        buf.extend_from_slice(&(junk.len() as u32).to_be_bytes());
        buf.extend_from_slice(junk);
        write_frame(&mut buf, &Frame::Ping { nonce: 5 }).unwrap();
        let mut cursor = Cursor::new(buf);
        match read_frame(&mut cursor, DEFAULT_MAX_FRAME_BYTES).unwrap() {
            ReadOutcome::Bad(e) => assert_eq!(e.code, ErrorCode::UnknownType),
            other => panic!("expected Bad, got {other:?}"),
        }
        match read_frame(&mut cursor, DEFAULT_MAX_FRAME_BYTES).unwrap() {
            ReadOutcome::Frame(Frame::Ping { nonce }) => assert_eq!(nonce, 5),
            other => panic!("expected ping, got {other:?}"),
        }
    }
}
