//! TCP front-end: thread-per-connection server over the wire protocol.
//!
//! [`Server::bind`] accepts connections on a `std::net` listener and
//! serves [`crate::serving::proto`] frames against a shared
//! [`Coordinator`] — since the sharding rework, a **pool** of batching
//! workers the coordinator routes into by model id; the server neither
//! knows nor cares, and the wire protocol is unchanged except for the
//! richer `metrics` frame (merged + per-shard counters).  No async
//! runtime exists in the offline build, so the design is the
//! contention-minimal std one: one accept thread, one thread per
//! connection (bounded by [`ServerConfig::max_connections`]), frames
//! handled serially per connection — responses come back in request
//! order on each socket.
//!
//! **Admission control** keeps overload typed instead of silent: an
//! `infer` frame is only submitted to the coordinator after taking one of
//! [`ServerConfig::max_inflight`] slots (held until its response is
//! written); at the cap the server immediately answers a
//! `RESOURCE_EXHAUSTED` error frame and keeps the connection open — the
//! socket never stalls behind an unbounded queue.  The connection cap
//! works the same way: an over-cap accept is answered with one
//! `RESOURCE_EXHAUSTED` frame and closed.
//!
//! Shutdown is clean by construction: [`Server::shutdown`] (also run on
//! drop) stops the accept loop, then every connection thread finishes the
//! request it is waiting on — the coordinator is kept alive by the
//! server's own `Arc` — writes the response, and exits; admitted requests
//! are never lost.

use crate::coordinator::server::Coordinator;
use crate::serving::proto::{
    self, ErrorCode, ErrorFrame, Frame, InferFrame, InferOkFrame, MetricsFrame, ModelsFrame,
    NetCounters,
};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Wall-clock grace a peer mid-frame gets to finish sending once
/// shutdown begins.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(2);

/// Tunables of the network front-end.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Concurrent connection cap; over-cap accepts get one
    /// `RESOURCE_EXHAUSTED` error frame and are closed.
    pub max_connections: usize,
    /// Admitted-but-unanswered `infer` cap across all connections; at the
    /// cap new infer frames get `RESOURCE_EXHAUSTED` (the connection
    /// stays open, the client may retry).
    pub max_inflight: usize,
    /// Per-frame payload size cap (bytes).
    pub max_frame_bytes: usize,
    /// How often blocked reads wake to check for shutdown.
    pub poll_interval: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_connections: 64,
            max_inflight: 256,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME_BYTES,
            poll_interval: Duration::from_millis(100),
        }
    }
}

/// Monotonic counters + gauges of the network layer (all atomic; shared
/// by every connection thread and snapshotted into the `metrics` frame).
#[derive(Debug, Default)]
struct NetMetrics {
    connections_opened: AtomicU64,
    connections_rejected: AtomicU64,
    frames_received: AtomicU64,
    frames_sent: AtomicU64,
    overload_rejections: AtomicU64,
    protocol_errors: AtomicU64,
    requests_failed: AtomicU64,
    requests_ok: AtomicU64,
}

/// State shared between the server handle, the accept thread, and every
/// connection thread.
struct Shared {
    coord: Arc<Coordinator>,
    config: ServerConfig,
    shutdown: AtomicBool,
    /// Gauge: connection threads currently alive.
    open: AtomicUsize,
    /// Gauge: infer requests admitted and not yet answered.
    inflight: AtomicUsize,
    metrics: NetMetrics,
    /// Connection thread handles, reaped opportunistically and joined on
    /// shutdown.
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn snapshot(&self) -> NetCounters {
        NetCounters {
            connections_open: self.open.load(Ordering::SeqCst) as u64,
            connections_opened: self.metrics.connections_opened.load(Ordering::SeqCst),
            connections_rejected: self.metrics.connections_rejected.load(Ordering::SeqCst),
            frames_received: self.metrics.frames_received.load(Ordering::SeqCst),
            frames_sent: self.metrics.frames_sent.load(Ordering::SeqCst),
            inflight: self.inflight.load(Ordering::SeqCst) as u64,
            overload_rejections: self.metrics.overload_rejections.load(Ordering::SeqCst),
            protocol_errors: self.metrics.protocol_errors.load(Ordering::SeqCst),
            requests_failed: self.metrics.requests_failed.load(Ordering::SeqCst),
            requests_ok: self.metrics.requests_ok.load(Ordering::SeqCst),
        }
    }
}

/// Handle to a running TCP serving front-end.  Dropping it shuts the
/// server down cleanly (in-flight requests finish first).
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting connections against `coord`.
    pub fn bind(
        addr: impl ToSocketAddrs,
        coord: Arc<Coordinator>,
        config: ServerConfig,
    ) -> Result<Server> {
        anyhow::ensure!(config.max_connections >= 1, "max_connections must be >= 1");
        anyhow::ensure!(config.max_inflight >= 1, "max_inflight must be >= 1");
        let listener = TcpListener::bind(addr).context("bind serving listener")?;
        let local = listener.local_addr().context("listener local addr")?;
        let shared = Arc::new(Shared {
            coord,
            config,
            shutdown: AtomicBool::new(false),
            open: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            metrics: NetMetrics::default(),
            conns: Mutex::new(Vec::new()),
        });
        let shared_accept = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("pasm-serving-accept".into())
            .spawn(move || accept_loop(listener, shared_accept))
            .context("spawn serving accept thread")?;
        Ok(Server { addr: local, shared, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The coordinator this server fronts.
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.shared.coord
    }

    /// Snapshot of the network-layer counters.
    pub fn net_metrics(&self) -> NetCounters {
        self.shared.snapshot()
    }

    /// Stop accepting, let every admitted request finish and its response
    /// be written, then join all threads.  Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // wake the blocking accept() with a throwaway connection; a
        // wildcard bind (0.0.0.0 / ::) is not connectable on every
        // platform, so aim the wake at the matching loopback address
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // transient accept failure (e.g. fd pressure): back off
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // connection cap: answer with one typed error frame and close
        let open = shared.open.load(Ordering::SeqCst);
        if open >= shared.config.max_connections {
            shared.metrics.connections_rejected.fetch_add(1, Ordering::SeqCst);
            let mut stream = stream;
            let frame = Frame::Error(ErrorFrame::new(
                None,
                ErrorCode::ResourceExhausted,
                format!("server at max connections ({})", shared.config.max_connections),
            ));
            let _ = proto::write_frame(&mut stream, &frame);
            continue;
        }
        shared.open.fetch_add(1, Ordering::SeqCst);
        shared.metrics.connections_opened.fetch_add(1, Ordering::SeqCst);
        let shared_conn = Arc::clone(&shared);
        let spawned = std::thread::Builder::new()
            .name("pasm-serving-conn".into())
            .spawn(move || {
                // decrement the open gauge even if the handler panics,
                // or the connection cap would leak slots
                let _open = OpenGuard(&shared_conn.open);
                connection_loop(stream, &shared_conn);
            });
        match spawned {
            Ok(handle) => {
                let mut conns = shared.conns.lock().unwrap();
                // opportunistically reap finished threads so a
                // long-running server does not accumulate handles
                let mut keep = Vec::with_capacity(conns.len() + 1);
                for h in conns.drain(..) {
                    if h.is_finished() {
                        let _ = h.join();
                    } else {
                        keep.push(h);
                    }
                }
                keep.push(handle);
                *conns = keep;
            }
            Err(_) => {
                shared.open.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// RAII decrement of the open-connections gauge (runs on panic too).
struct OpenGuard<'a>(&'a AtomicUsize);

impl Drop for OpenGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// What one shutdown-aware full read produced.
enum FullRead {
    /// The buffer was filled.
    Done,
    /// Clean EOF before the first byte.
    Eof,
    /// Shutdown was requested while idle at a frame boundary.
    Shutdown,
}

/// Fill `buf` from `stream`, tolerating read timeouts (the socket has
/// [`ServerConfig::poll_interval`] as its read timeout so blocked reads
/// can observe `shutdown`).  Partial frames are never abandoned: once the
/// first byte arrived, shutdown gives the peer [`SHUTDOWN_GRACE`] of
/// wall clock to finish the frame.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    shutdown: &AtomicBool,
) -> std::io::Result<FullRead> {
    use std::io::Read;
    let mut filled = 0usize;
    let mut shutdown_deadline: Option<Instant> = None;
    while filled < buf.len() {
        if shutdown.load(Ordering::SeqCst) {
            if filled == 0 {
                return Ok(FullRead::Shutdown);
            }
            let deadline =
                *shutdown_deadline.get_or_insert_with(|| Instant::now() + SHUTDOWN_GRACE);
            if Instant::now() > deadline {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "peer stalled mid-frame during shutdown",
                ));
            }
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(FullRead::Eof)
                } else {
                    Err(std::io::ErrorKind::UnexpectedEof.into())
                };
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(FullRead::Done)
}

/// Serve one connection until EOF, shutdown, or an unrecoverable
/// transport/framing error.
fn connection_loop(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.config.poll_interval));
    loop {
        let mut header = [0u8; 4];
        match read_full(&mut stream, &mut header, &shared.shutdown) {
            Ok(FullRead::Done) => {}
            Ok(FullRead::Eof) | Ok(FullRead::Shutdown) | Err(_) => return,
        }
        let len = u32::from_be_bytes(header) as usize;
        if len > shared.config.max_frame_bytes {
            // framing can no longer be trusted: answer once, then close
            shared.metrics.protocol_errors.fetch_add(1, Ordering::SeqCst);
            let frame = Frame::Error(ErrorFrame::new(
                None,
                ErrorCode::InvalidFrame,
                format!(
                    "frame of {len} bytes exceeds the {}-byte cap",
                    shared.config.max_frame_bytes
                ),
            ));
            send(&mut stream, shared, &frame);
            return;
        }
        let mut payload = vec![0u8; len];
        match read_full(&mut stream, &mut payload, &shared.shutdown) {
            Ok(FullRead::Done) => {}
            Ok(FullRead::Eof) | Ok(FullRead::Shutdown) | Err(_) => return,
        }
        shared.metrics.frames_received.fetch_add(1, Ordering::SeqCst);
        let frame = match proto::decode(&payload) {
            Ok(frame) => frame,
            Err(e) => {
                // well-framed but undecodable: typed error, keep serving
                shared.metrics.protocol_errors.fetch_add(1, Ordering::SeqCst);
                send(&mut stream, shared, &Frame::Error(e));
                continue;
            }
        };
        // the admission slot (for infer frames) is released only after
        // the reply is written, so the inflight gauge also covers
        // responses stuck behind a slow-reading client
        let (reply, slot) = handle_frame(frame, shared);
        send(&mut stream, shared, &reply);
        drop(slot);
    }
}

fn send(stream: &mut TcpStream, shared: &Shared, frame: &Frame) {
    if proto::write_frame(stream, frame).is_ok() {
        shared.metrics.frames_sent.fetch_add(1, Ordering::SeqCst);
    }
}

/// Dispatch one decoded client frame to its reply frame (plus, for infer
/// frames, the admission slot the caller must hold until the reply is
/// written).
fn handle_frame(frame: Frame, shared: &Shared) -> (Frame, Option<InflightSlot<'_>>) {
    match frame {
        Frame::Infer(req) => handle_infer(req, shared),
        Frame::ListModels => {
            let coord = &shared.coord;
            let reply = Frame::Models(ModelsFrame {
                models: coord.registry().map(|r| r.names()).unwrap_or_default(),
                default: coord.default_model().map(str::to_string),
            });
            (reply, None)
        }
        Frame::GetMetrics => {
            // merged across the shard pool, plus the per-shard counters —
            // the only place sharding is visible on the wire.  One
            // consistent snapshot: the counters must sum to the merged
            // totals even under live traffic.
            let (m, shards) = shared.coord.metrics_with_shards();
            let reply = Frame::Metrics(MetricsFrame {
                backend: m.backend.clone(),
                requests: m.requests,
                batches: m.batches,
                failed_batches: m.failed_batches,
                p50_us: m.percentile_us(50.0),
                p90_us: m.percentile_us(90.0),
                p99_us: m.percentile_us(99.0),
                per_model: m.per_model.clone(),
                shards,
                net: shared.snapshot(),
            });
            (reply, None)
        }
        Frame::Ping { nonce } => (Frame::Pong { nonce }, None),
        // server-to-client frames arriving at the server
        other => (
            Frame::Error(ErrorFrame::new(
                None,
                ErrorCode::InvalidFrame,
                format!("servers do not accept '{}' frames", other.type_str()),
            )),
            None,
        ),
    }
}

/// RAII slot of the in-flight admission gauge.
struct InflightSlot<'a>(&'a AtomicUsize);

impl<'a> InflightSlot<'a> {
    /// Take a slot unless the gauge is at `cap`.
    fn acquire(gauge: &'a AtomicUsize, cap: usize) -> Option<Self> {
        gauge
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                if n < cap {
                    Some(n + 1)
                } else {
                    None
                }
            })
            .ok()
            .map(|_| InflightSlot(gauge))
    }
}

impl Drop for InflightSlot<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn handle_infer(req: InferFrame, shared: &Shared) -> (Frame, Option<InflightSlot<'_>>) {
    let id = Some(req.id);
    let err = |code: ErrorCode, msg: String| Frame::Error(ErrorFrame::new(id, code, msg));

    // admission control first: reject before any validation work
    let Some(slot) = InflightSlot::acquire(&shared.inflight, shared.config.max_inflight) else {
        shared.metrics.overload_rejections.fetch_add(1, Ordering::SeqCst);
        let reply = err(
            ErrorCode::ResourceExhausted,
            format!("server at max in-flight requests ({})", shared.config.max_inflight),
        );
        return (reply, None);
    };
    let slot = Some(slot);

    // checked product: a crafted dims array must not wrap around to a
    // plausible volume (or panic the thread in a debug build)
    let volume = req.dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d));
    let valid = matches!(volume, Some(v) if req.dims.len() == 3 && v > 0 && v == req.data.len());
    if !valid {
        let reply = err(
            ErrorCode::BadImage,
            format!(
                "dims {:?} do not describe the {}-element data array",
                req.dims,
                req.data.len()
            ),
        );
        return (reply, slot);
    }
    if !req.data.iter().all(|x| x.is_finite()) {
        return (err(ErrorCode::BadImage, "image data contains non-finite values".into()), slot);
    }
    let image = Tensor::from_vec(&req.dims, req.data);

    // pre-resolve the model name for a deterministic typed error (the
    // engine would also reject it, but post-batching and stringly)
    if let Some(model) = &req.model {
        match shared.coord.registry() {
            Some(reg) => {
                if reg.get(model).is_none() {
                    let reply = err(
                        ErrorCode::UnknownModel,
                        format!("model '{model}' is not in the registry"),
                    );
                    return (reply, slot);
                }
            }
            None => {
                let reply = err(
                    ErrorCode::UnknownModel,
                    format!("request names model '{model}' but the server has no registry"),
                );
                return (reply, slot);
            }
        }
    }

    let submitted = match &req.model {
        Some(model) => shared.coord.submit_to(model, image),
        None => shared.coord.submit(image),
    };
    let rx = match submitted {
        Ok(rx) => rx,
        Err(_) => {
            shared.metrics.requests_failed.fetch_add(1, Ordering::SeqCst);
            return (err(ErrorCode::ShuttingDown, "coordinator is shut down".into()), slot);
        }
    };
    let reply = match rx.recv() {
        Ok(Ok(resp)) => {
            shared.metrics.requests_ok.fetch_add(1, Ordering::SeqCst);
            Frame::InferOk(InferOkFrame {
                id: req.id,
                model: resp.model.as_deref().map(str::to_string),
                logits: resp.logits,
                predicted: resp.predicted,
                queue_us: resp.queue_us,
                compute_us: resp.compute_us,
                batch_size: resp.batch_size,
                batch_occupancy: resp.batch_occupancy,
                hw: resp.hw,
            })
        }
        Ok(Err(msg)) => {
            shared.metrics.requests_failed.fetch_add(1, Ordering::SeqCst);
            // a hot-removed model loses the pre-check race above; keep
            // the error typed by recognizing the engine's message
            let code = if msg.contains("is not in the registry") {
                ErrorCode::UnknownModel
            } else {
                ErrorCode::Internal
            };
            err(code, msg)
        }
        Err(_) => {
            shared.metrics.requests_failed.fetch_add(1, Ordering::SeqCst);
            err(ErrorCode::Internal, "coordinator dropped the request".into())
        }
    };
    (reply, slot)
}

/// Write the bound address to `path` atomically (temp file + rename), so
/// a script that started the server on an ephemeral port (`--listen
/// 127.0.0.1:0`) can read the real address without racing a partial
/// write.  Used by `repro serve --port-file` and the CI quickstart check.
pub fn write_port_file(path: &std::path::Path, addr: SocketAddr) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        write!(f, "{addr}").with_context(|| format!("write {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} into place", path.display()))?;
    Ok(())
}
