//! TCP front-end: thread-per-connection server over the wire protocol.
//!
//! [`Server::bind`] accepts connections on a `std::net` listener and
//! serves [`crate::serving::proto`] frames against a shared
//! [`Coordinator`] — since the sharding rework, a **pool** of batching
//! workers the coordinator routes into by model id; the server neither
//! knows nor cares, and the wire protocol is unchanged except for the
//! richer `metrics` frame (merged + per-shard counters).  No async
//! runtime exists in the offline build, so the design is the
//! contention-minimal std one: one accept thread, one thread per
//! connection (bounded by [`ServerConfig::max_connections`]), frames
//! handled serially per connection — responses come back in request
//! order on each socket.
//!
//! **Admission control** keeps overload typed instead of silent: an
//! `infer` frame is only submitted to the coordinator after taking one of
//! [`ServerConfig::max_inflight`] slots (held until its response is
//! written); at the cap the server immediately answers a
//! `RESOURCE_EXHAUSTED` error frame and keeps the connection open — the
//! socket never stalls behind an unbounded queue.  The connection cap
//! works the same way: an over-cap accept is answered with one
//! `RESOURCE_EXHAUSTED` frame and closed.
//!
//! Shutdown is clean by construction: [`Server::shutdown`] (also run on
//! drop) stops the accept loop, then every connection thread finishes the
//! request it is waiting on — the coordinator is kept alive by the
//! server's own `Arc` — writes the response, and exits; admitted requests
//! are never lost.

use crate::coordinator::request::Ingress;
use crate::coordinator::server::Coordinator;
use crate::faults::FaultSite;
use crate::serving::proto::{self, ErrorCode, ErrorFrame, Frame, InferFrame, NetCounters};
use crate::serving::shared::{self as common, InflightSlot, NetMetrics, ReplyTrace, ValidInfer};
use anyhow::{Context, Result};
use std::io::Write;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Wall-clock grace a peer mid-frame gets to finish sending once
/// shutdown begins.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(2);

/// Tunables of the network front-end.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Concurrent connection cap; over-cap accepts get one
    /// `RESOURCE_EXHAUSTED` error frame and are closed.
    pub max_connections: usize,
    /// Admitted-but-unanswered `infer` cap across all connections; at the
    /// cap new infer frames get `RESOURCE_EXHAUSTED` (the connection
    /// stays open, the client may retry).
    pub max_inflight: usize,
    /// Per-frame payload size cap (bytes).
    pub max_frame_bytes: usize,
    /// How often blocked reads wake to check for shutdown.
    pub poll_interval: Duration,
    /// Close a connection that has been idle (no request in flight, not
    /// a single byte of a new frame received) for this long, so half-open
    /// or abandoned clients cannot hold connection slots forever.
    pub idle_timeout: Duration,
    /// Once the first byte of a frame has arrived, the rest must follow
    /// within this budget or the connection is closed — a slow-loris
    /// peer trickling one byte at a time cannot pin a connection slot.
    pub frame_timeout: Duration,
    /// Socket write timeout: a peer that stops draining its responses is
    /// disconnected instead of blocking the connection thread forever.
    pub write_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_connections: 64,
            max_inflight: 256,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME_BYTES,
            poll_interval: Duration::from_millis(100),
            idle_timeout: Duration::from_secs(60),
            frame_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
        }
    }
}

/// State shared between the server handle, the accept thread, and every
/// connection thread.
struct Shared {
    coord: Arc<Coordinator>,
    config: ServerConfig,
    shutdown: AtomicBool,
    /// Gauge: connection threads currently alive.
    open: AtomicUsize,
    /// Gauge: infer requests admitted and not yet answered (`Arc` so
    /// [`InflightSlot`]s can own a handle to it).
    inflight: Arc<AtomicUsize>,
    metrics: NetMetrics,
    /// Connection thread handles, reaped opportunistically and joined on
    /// shutdown.
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn snapshot(&self) -> NetCounters {
        self.metrics
            .snapshot(self.open.load(Ordering::SeqCst), self.inflight.load(Ordering::SeqCst))
    }
}

/// Handle to a running TCP serving front-end.  Dropping it shuts the
/// server down cleanly (in-flight requests finish first).
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting connections against `coord`.
    pub fn bind(
        addr: impl ToSocketAddrs,
        coord: Arc<Coordinator>,
        config: ServerConfig,
    ) -> Result<Server> {
        anyhow::ensure!(config.max_connections >= 1, "max_connections must be >= 1");
        anyhow::ensure!(config.max_inflight >= 1, "max_inflight must be >= 1");
        let listener = TcpListener::bind(addr).context("bind serving listener")?;
        let local = listener.local_addr().context("listener local addr")?;
        let shared = Arc::new(Shared {
            coord,
            config,
            shutdown: AtomicBool::new(false),
            open: AtomicUsize::new(0),
            inflight: Arc::new(AtomicUsize::new(0)),
            metrics: NetMetrics::default(),
            conns: Mutex::new(Vec::new()),
        });
        let shared_accept = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("pasm-serving-accept".into())
            .spawn(move || accept_loop(listener, shared_accept))
            .context("spawn serving accept thread")?;
        Ok(Server { addr: local, shared, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The coordinator this server fronts.
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.shared.coord
    }

    /// Snapshot of the network-layer counters.
    pub fn net_metrics(&self) -> NetCounters {
        self.shared.snapshot()
    }

    /// Stop accepting, let every admitted request finish and its response
    /// be written, then join all threads.  Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // wake the blocking accept() with a throwaway connection; a
        // wildcard bind (0.0.0.0 / ::) is not connectable on every
        // platform, so aim the wake at the matching loopback address
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *common::lock_unpoisoned(&self.shared.conns));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // transient accept failure (e.g. fd pressure): back off
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // connection cap: answer with one typed error frame and close
        let open = shared.open.load(Ordering::SeqCst);
        if open >= shared.config.max_connections {
            shared.metrics.connections_rejected.fetch_add(1, Ordering::SeqCst);
            let mut stream = stream;
            let frame = Frame::Error(ErrorFrame::new(
                None,
                ErrorCode::ResourceExhausted,
                format!("server at max connections ({})", shared.config.max_connections),
            ));
            let _ = proto::write_frame(&mut stream, &frame);
            continue;
        }
        shared.open.fetch_add(1, Ordering::SeqCst);
        shared.metrics.connections_opened.fetch_add(1, Ordering::SeqCst);
        let shared_conn = Arc::clone(&shared);
        let spawned = std::thread::Builder::new()
            .name("pasm-serving-conn".into())
            .spawn(move || {
                // decrement the open gauge even if the handler panics,
                // or the connection cap would leak slots
                let _open = OpenGuard(&shared_conn.open);
                connection_loop(stream, &shared_conn);
            });
        match spawned {
            Ok(handle) => {
                let mut conns = common::lock_unpoisoned(&shared.conns);
                // opportunistically reap finished threads so a
                // long-running server does not accumulate handles
                let mut keep = Vec::with_capacity(conns.len() + 1);
                for h in conns.drain(..) {
                    if h.is_finished() {
                        let _ = h.join();
                    } else {
                        keep.push(h);
                    }
                }
                keep.push(handle);
                *conns = keep;
            }
            Err(_) => {
                shared.open.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// RAII decrement of the open-connections gauge (runs on panic too).
struct OpenGuard<'a>(&'a AtomicUsize);

impl Drop for OpenGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// What one shutdown-aware full read produced.
enum FullRead {
    /// The buffer was filled.
    Done,
    /// Clean EOF before the first byte.
    Eof,
    /// Shutdown was requested while idle at a frame boundary.
    Shutdown,
    /// [`ServerConfig::idle_timeout`] expired before a new frame began.
    Idle,
    /// [`ServerConfig::frame_timeout`] expired mid-frame — a slow-loris
    /// peer trickling bytes is reaped rather than waited on.
    Loris,
}

/// Fill `buf` from `stream`, tolerating read timeouts (the socket has
/// [`ServerConfig::poll_interval`] as its read timeout so blocked reads
/// can observe `shutdown` and the deadlines).  Partial frames are never
/// abandoned to shutdown: once the first byte of a frame arrived,
/// shutdown gives the peer [`SHUTDOWN_GRACE`] of wall clock to finish it.
///
/// `idle_deadline` applies only while no byte of the current frame has
/// arrived (reaping idle/half-open peers between frames);
/// `frame_deadline` is set at the frame's first byte and shared between
/// the header and payload reads, so a slow-loris peer trickling bytes
/// cannot stretch a single frame forever.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    shared: &Shared,
    idle_deadline: Option<Instant>,
    frame_deadline: &mut Option<Instant>,
) -> std::io::Result<FullRead> {
    use std::io::Read;
    let mut filled = 0usize;
    let mut shutdown_deadline: Option<Instant> = None;
    while filled < buf.len() {
        if shared.shutdown.load(Ordering::SeqCst) {
            if filled == 0 && frame_deadline.is_none() {
                return Ok(FullRead::Shutdown);
            }
            let deadline =
                *shutdown_deadline.get_or_insert_with(|| Instant::now() + SHUTDOWN_GRACE);
            if Instant::now() > deadline {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "peer stalled mid-frame during shutdown",
                ));
            }
        } else {
            match *frame_deadline {
                None => {
                    if let Some(idle) = idle_deadline {
                        if Instant::now() > idle {
                            return Ok(FullRead::Idle);
                        }
                    }
                }
                Some(deadline) => {
                    if Instant::now() > deadline {
                        return Ok(FullRead::Loris);
                    }
                }
            }
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(FullRead::Eof)
                } else {
                    Err(std::io::ErrorKind::UnexpectedEof.into())
                };
            }
            Ok(n) => {
                filled += n;
                if frame_deadline.is_none() {
                    *frame_deadline = Some(Instant::now() + shared.config.frame_timeout);
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(FullRead::Done)
}

/// Serve one connection until EOF, shutdown, a timeout reap, or an
/// unrecoverable transport/framing error.
fn connection_loop(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(shared.config.poll_interval));
    let _ = stream.set_write_timeout(Some(shared.config.write_timeout));
    loop {
        // both reap deadlines restart at each frame boundary
        let idle = Instant::now() + shared.config.idle_timeout;
        let mut frame_deadline: Option<Instant> = None;
        let mut header = [0u8; 4];
        match read_full(&mut stream, &mut header, shared, Some(idle), &mut frame_deadline) {
            Ok(FullRead::Done) => {}
            Ok(FullRead::Idle) => {
                shared.metrics.idle_reaped.fetch_add(1, Ordering::SeqCst);
                return;
            }
            Ok(FullRead::Loris) => {
                shared.metrics.loris_reaped.fetch_add(1, Ordering::SeqCst);
                return;
            }
            Ok(_) | Err(_) => return,
        }
        // `accepted` anchors the request's lifecycle span at the instant
        // its frame header completed, before any payload or decode work
        let accepted = Instant::now();
        let len = u32::from_be_bytes(header) as usize;
        if len > shared.config.max_frame_bytes {
            // framing can no longer be trusted: answer once, then close
            shared.metrics.protocol_errors.fetch_add(1, Ordering::SeqCst);
            let frame = Frame::Error(ErrorFrame::new(
                None,
                ErrorCode::InvalidFrame,
                format!(
                    "frame of {len} bytes exceeds the {}-byte cap",
                    shared.config.max_frame_bytes
                ),
            ));
            let _ = send(&mut stream, shared, &frame);
            return;
        }
        let mut payload = vec![0u8; len];
        match read_full(&mut stream, &mut payload, shared, None, &mut frame_deadline) {
            Ok(FullRead::Done) => {}
            Ok(FullRead::Loris) => {
                shared.metrics.loris_reaped.fetch_add(1, Ordering::SeqCst);
                return;
            }
            Ok(_) | Err(_) => return,
        }
        shared.metrics.frames_received.fetch_add(1, Ordering::SeqCst);
        let frame = match proto::decode(&payload) {
            Ok(frame) => frame,
            Err(e) => {
                // well-framed but undecodable: typed error, keep serving
                shared.metrics.protocol_errors.fetch_add(1, Ordering::SeqCst);
                if send(&mut stream, shared, &Frame::Error(e)).is_none() {
                    return;
                }
                continue;
            }
        };
        let ingress = Ingress { accepted, decoded: Instant::now() };
        // the admission slot (for infer frames) is released only after
        // the reply is written, so the inflight gauge also covers
        // responses stuck behind a slow-reading client
        let (reply, slot, trace) = handle_frame(frame, shared, ingress);
        // fault injection: a chaos plan may reset the socket instead of
        // answering — the client sees a dropped connection and (with a
        // retry policy) resubmits; the admission slot is still released
        if let Some(plan) = shared.coord.fault_plan() {
            if plan.should(FaultSite::SocketReset) {
                return;
            }
        }
        let write_started = Instant::now();
        let sent = send(&mut stream, shared, &reply);
        if let (Some(bytes), Some(t)) = (sent, &trace) {
            t.finish(&shared.coord, write_started.elapsed(), bytes);
        }
        drop(slot);
        if sent.is_none() {
            // a failed/timed-out write leaves the peer's framing state
            // unknowable; close instead of serving a corrupt stream
            return;
        }
    }
}

/// Write one frame; `Some(payload_bytes)` on success (the write-back aux
/// the tracer records), `None` on a failed or timed-out write.
fn send(stream: &mut TcpStream, shared: &Shared, frame: &Frame) -> Option<usize> {
    let payload = proto::encode(frame);
    let len = u32::try_from(payload.len()).ok()?;
    let wrote = stream
        .write_all(&len.to_be_bytes())
        .and_then(|()| stream.write_all(&payload))
        .and_then(|()| stream.flush());
    if wrote.is_ok() {
        shared.metrics.frames_sent.fetch_add(1, Ordering::SeqCst);
        Some(payload.len())
    } else {
        None
    }
}

/// Dispatch one decoded client frame to its reply frame (plus, for infer
/// frames, the admission slot the caller must hold until the reply is
/// written and the span bookkeeping to finish after the write).
fn handle_frame(
    frame: Frame,
    shared: &Shared,
    ingress: Ingress,
) -> (Frame, Option<InflightSlot>, Option<ReplyTrace>) {
    match frame {
        Frame::Infer(req) => handle_infer(req, shared, ingress),
        // this transport is serial by construction: grant no pipelining,
        // whatever the client asked for (the evented server grants it)
        Frame::Hello { .. } => (Frame::HelloOk { pipeline: false, depth: 1 }, None, None),
        Frame::ListModels => (common::models_frame(&shared.coord), None, None),
        Frame::GetMetrics => (common::metrics_frame(&shared.coord, shared.snapshot()), None, None),
        Frame::GetTrace { id, limit } => {
            (common::trace_frame(&shared.coord, id, limit), None, None)
        }
        Frame::Ping { nonce } => (Frame::Pong { nonce }, None, None),
        // server-to-client frames arriving at the server
        other => (common::wrong_direction_frame(&other), None, None),
    }
}

fn handle_infer(
    req: InferFrame,
    shared: &Shared,
    ingress: Ingress,
) -> (Frame, Option<InflightSlot>, Option<ReplyTrace>) {
    let req_id = req.id;
    let err = |code: ErrorCode, msg: String| Frame::Error(ErrorFrame::new(Some(req_id), code, msg));

    // admission control first: reject before any validation work
    let Some(slot) = InflightSlot::acquire(&shared.inflight, shared.config.max_inflight) else {
        shared.metrics.overload_rejections.fetch_add(1, Ordering::SeqCst);
        let reply = err(
            ErrorCode::ResourceExhausted,
            format!("server at max in-flight requests ({})", shared.config.max_inflight),
        );
        return (reply, None, None);
    };
    let slot = Some(slot);

    let valid = match common::validate_infer(req, &shared.coord) {
        Ok(v) => v,
        Err(reply) => return (reply, slot, None),
    };
    let ValidInfer { id, model, image, deadline } = valid;

    let submitted = shared.coord.submit_traced(model.as_deref(), image, deadline, Some(ingress));
    let (coord_id, rx) = match submitted {
        Ok(pair) => pair,
        Err(e) => {
            shared.metrics.requests_failed.fetch_add(1, Ordering::SeqCst);
            let msg = e.to_string();
            let code = if msg.contains("unavailable") {
                // a dying shard is transient (the supervisor respawns it)
                ErrorCode::Unavailable
            } else {
                ErrorCode::ShuttingDown
            };
            return (err(code, msg), slot, None);
        }
    };
    let trace = ReplyTrace {
        shard: shared.coord.shard_for(model.as_deref()),
        coord_id,
        model,
        retry_code: None,
    };
    let reply = match rx.recv() {
        Ok(Ok(resp)) => {
            shared.metrics.requests_ok.fetch_add(1, Ordering::SeqCst);
            common::infer_ok_frame(id, resp)
        }
        Ok(Err(msg)) => {
            shared.metrics.requests_failed.fetch_add(1, Ordering::SeqCst);
            common::infer_err_frame(id, msg)
        }
        Err(_) => {
            shared.metrics.requests_failed.fetch_add(1, Ordering::SeqCst);
            err(ErrorCode::Unavailable, "coordinator dropped the request".into())
        }
    };
    let trace = trace.observe(&reply);
    (reply, slot, Some(trace))
}

/// Write the bound address to `path` atomically (temp file + rename), so
/// a script that started the server on an ephemeral port (`--listen
/// 127.0.0.1:0`) can read the real address without racing a partial
/// write.  Used by `repro serve --port-file` and the CI quickstart check.
pub fn write_port_file(path: &std::path::Path, addr: SocketAddr) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        write!(f, "{addr}").with_context(|| format!("write {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} into place", path.display()))?;
    Ok(())
}
