//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a seeded, probabilistic schedule of failures that
//! the coordinator, the model registry, and both serving front-ends
//! consult at well-defined injection points ([`FaultSite`]): batch
//! execution errors, kernel panics, injected pre-batch latency, shard
//! worker deaths, torn `.pasm` artifact loads, and server-side socket
//! resets.  The module is **always compiled in** — there is no cfg flag
//! to forget in production builds — and a stack with no plan attached
//! (or a plan whose probabilities are all zero) takes the exact same
//! code paths with zero injected faults.
//!
//! Decisions are **deterministic**: the n-th roll at a given site is a
//! pure function of `(seed, site, n)`, independent of thread timing, so
//! a chaos run replays identically for a given request schedule and two
//! identically seeded plans agree roll for roll.  Every triggered fault
//! increments a per-site counter ([`FaultPlan::counters`]); a clean run
//! must end with [`FaultCounters::total`] of zero, which is how the
//! chaos e2e proves the injection layer is inert when disabled.
//!
//! Plans come from code ([`FaultPlan::seeded`] + the `with_*` setters)
//! or from a compact CLI spec ([`FaultPlan::parse`]), e.g.
//! `repro serve --chaos seed=7,panic=0.05,reset=0.02`.

use crate::cnn::data::Rng;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// An injection point in the serving stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Batch execution returns an error instead of running the kernel
    /// (the whole batch fails with a typed `INTERNAL` reply).
    ExecError,
    /// The kernel panics inside `run_batch`; the per-batch
    /// `catch_unwind` in the shard worker must contain it.
    BatchPanic,
    /// Extra latency is injected before a batch launches (drives
    /// deadline misses under load).
    Latency,
    /// The shard worker thread dies before serving the selected batch;
    /// the supervisor must fail the stranded requests and respawn it.
    WorkerKill,
    /// A `.pasm` artifact load is reported torn/corrupt; the registry
    /// must keep the previous version serving.
    TornLoad,
    /// The server drops the connection instead of answering a frame.
    SocketReset,
}

const SITES: usize = 6;

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::ExecError => 0,
            FaultSite::BatchPanic => 1,
            FaultSite::Latency => 2,
            FaultSite::WorkerKill => 3,
            FaultSite::TornLoad => 4,
            FaultSite::SocketReset => 5,
        }
    }
}

/// Counts of faults actually injected, one per [`FaultSite`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Batches failed with an injected execution error.
    pub exec_errors: u64,
    /// Batches failed with an injected kernel panic.
    pub panics: u64,
    /// Batches delayed by injected latency.
    pub latency_injections: u64,
    /// Shard workers killed.
    pub worker_kills: u64,
    /// Artifact loads reported torn.
    pub torn_loads: u64,
    /// Connections dropped instead of answered.
    pub socket_resets: u64,
}

impl FaultCounters {
    /// Total faults injected across every site.
    pub fn total(&self) -> u64 {
        self.exec_errors
            + self.panics
            + self.latency_injections
            + self.worker_kills
            + self.torn_loads
            + self.socket_resets
    }
}

/// A seeded, deterministic schedule of injected faults.
///
/// Thread-safe: injection points share one plan behind an `Arc` and
/// roll concurrently; per-site atomic draw counters keep each site's
/// roll sequence deterministic in aggregate (the set of outcomes over
/// n draws is fixed; which thread observes which draw is not).
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Trigger probability per site, in `[0, 1]`.
    probs: [f64; SITES],
    /// Injected latency amount for [`FaultSite::Latency`] triggers.
    latency: Duration,
    /// Draws made per site (deterministic sequence position).
    draws: [AtomicU64; SITES],
    /// Faults actually injected per site.
    hits: [AtomicU64; SITES],
}

impl FaultPlan {
    /// An inert plan (all probabilities zero) with the given seed.
    pub fn seeded(seed: u64) -> Self {
        FaultPlan {
            seed,
            probs: [0.0; SITES],
            latency: Duration::from_millis(5),
            draws: Default::default(),
            hits: Default::default(),
        }
    }

    /// Parse a compact `key=value` spec, e.g.
    /// `seed=7,panic=0.05,reset=0.02,latency=0.1,latency_ms=5`.
    ///
    /// Keys: `seed` (u64, default 1), `exec`, `panic`, `latency`,
    /// `kill`, `torn`, `reset` (probabilities in `[0, 1]`, default 0),
    /// and `latency_ms` (injected delay, default 5).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::seeded(1);
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .with_context(|| format!("chaos spec '{part}': expected key=value"))?;
            let parse_p = || -> Result<f64> {
                let p: f64 = value
                    .parse()
                    .with_context(|| format!("chaos spec '{part}': not a number"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&p),
                    "chaos spec '{part}': probability must be in [0, 1]"
                );
                Ok(p)
            };
            match key {
                "seed" => {
                    plan.seed =
                        value.parse().with_context(|| format!("chaos spec '{part}': bad seed"))?;
                }
                "exec" => plan.probs[FaultSite::ExecError.index()] = parse_p()?,
                "panic" => plan.probs[FaultSite::BatchPanic.index()] = parse_p()?,
                "latency" => plan.probs[FaultSite::Latency.index()] = parse_p()?,
                "kill" => plan.probs[FaultSite::WorkerKill.index()] = parse_p()?,
                "torn" => plan.probs[FaultSite::TornLoad.index()] = parse_p()?,
                "reset" => plan.probs[FaultSite::SocketReset.index()] = parse_p()?,
                "latency_ms" => {
                    let ms: u64 = value
                        .parse()
                        .with_context(|| format!("chaos spec '{part}': bad latency_ms"))?;
                    plan.latency = Duration::from_millis(ms);
                }
                other => anyhow::bail!(
                    "chaos spec: unknown key '{other}' \
                     (expected seed, exec, panic, latency, kill, torn, reset, latency_ms)"
                ),
            }
        }
        Ok(plan)
    }

    /// Set the trigger probability for one site (builder style).
    pub fn with(mut self, site: FaultSite, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "fault probability must be in [0, 1]");
        self.probs[site.index()] = p;
        self
    }

    /// Set the delay injected on [`FaultSite::Latency`] triggers.
    pub fn with_latency(mut self, latency: Duration) -> Self {
        self.latency = latency;
        self
    }

    /// The plan's seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Roll the dice at `site`: `true` means inject the fault (and the
    /// site's hit counter was incremented).  The n-th call for a site
    /// returns a fixed answer for a given seed.
    pub fn should(&self, site: FaultSite) -> bool {
        let i = site.index();
        let p = self.probs[i];
        if p <= 0.0 {
            return false;
        }
        let n = self.draws[i].fetch_add(1, Ordering::Relaxed);
        // decorrelate (seed, site, n) into an independent stream: a few
        // xorshift* steps over a splitmix-style mix of the inputs
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ (i as u64 + 1).wrapping_mul(0xbf58_476d_1ce4_e5b9)
                ^ n.wrapping_mul(0x94d0_49bb_1331_11eb),
        );
        rng.next_u64();
        let hit = f64::from(rng.uniform()) < p;
        if hit {
            self.hits[i].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Roll [`FaultSite::Latency`]; `Some(delay)` means sleep that long
    /// before launching the batch.
    pub fn injected_latency(&self) -> Option<Duration> {
        self.should(FaultSite::Latency).then_some(self.latency)
    }

    /// Snapshot of every site's injected-fault count.
    pub fn counters(&self) -> FaultCounters {
        let h = |s: FaultSite| self.hits[s.index()].load(Ordering::Relaxed);
        FaultCounters {
            exec_errors: h(FaultSite::ExecError),
            panics: h(FaultSite::BatchPanic),
            latency_injections: h(FaultSite::Latency),
            worker_kills: h(FaultSite::WorkerKill),
            torn_loads: h(FaultSite::TornLoad),
            socket_resets: h(FaultSite::SocketReset),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_cli_spec() {
        let plan = FaultPlan::parse("seed=7,panic=0.05,reset=0.02,latency_ms=9").unwrap();
        assert_eq!(plan.seed(), 7);
        assert_eq!(plan.probs[FaultSite::BatchPanic.index()], 0.05);
        assert_eq!(plan.probs[FaultSite::SocketReset.index()], 0.02);
        assert_eq!(plan.latency, Duration::from_millis(9));
        assert_eq!(plan.probs[FaultSite::ExecError.index()], 0.0);
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("panic=1.5").is_err());
        assert!(FaultPlan::parse("panic").is_err());
    }

    #[test]
    fn empty_spec_and_zero_probabilities_are_inert() {
        let plan = FaultPlan::parse("").unwrap();
        for site in [
            FaultSite::ExecError,
            FaultSite::BatchPanic,
            FaultSite::Latency,
            FaultSite::WorkerKill,
            FaultSite::TornLoad,
            FaultSite::SocketReset,
        ] {
            for _ in 0..100 {
                assert!(!plan.should(site));
            }
        }
        assert_eq!(plan.counters().total(), 0);
        assert_eq!(plan.injected_latency(), None);
    }

    #[test]
    fn rolls_are_deterministic_per_seed_and_site() {
        let a = FaultPlan::seeded(42).with(FaultSite::BatchPanic, 0.3);
        let b = FaultPlan::seeded(42).with(FaultSite::BatchPanic, 0.3);
        let seq_a: Vec<bool> = (0..200).map(|_| a.should(FaultSite::BatchPanic)).collect();
        let seq_b: Vec<bool> = (0..200).map(|_| b.should(FaultSite::BatchPanic)).collect();
        assert_eq!(seq_a, seq_b, "same seed must produce the same roll sequence");
        let hits = seq_a.iter().filter(|&&h| h).count() as u64;
        assert!(hits > 0, "p=0.3 over 200 rolls must trigger");
        assert_eq!(a.counters().panics, hits);
        assert_eq!(a.counters().total(), hits);

        let c = FaultPlan::seeded(43).with(FaultSite::BatchPanic, 0.3);
        let seq_c: Vec<bool> = (0..200).map(|_| c.should(FaultSite::BatchPanic)).collect();
        assert_ne!(seq_a, seq_c, "different seeds must diverge");
    }

    #[test]
    fn sites_draw_independent_streams() {
        let plan = FaultPlan::seeded(5)
            .with(FaultSite::ExecError, 0.5)
            .with(FaultSite::SocketReset, 0.5);
        let a: Vec<bool> = (0..64).map(|_| plan.should(FaultSite::ExecError)).collect();
        let b: Vec<bool> = (0..64).map(|_| plan.should(FaultSite::SocketReset)).collect();
        assert_ne!(a, b, "two sites at the same seed must not share a stream");
    }

    #[test]
    fn hit_rate_tracks_the_probability() {
        let plan = FaultPlan::seeded(11).with(FaultSite::TornLoad, 0.2);
        let n = 5000;
        let hits = (0..n).filter(|_| plan.should(FaultSite::TornLoad)).count();
        let rate = hits as f64 / f64::from(n);
        assert!((rate - 0.2).abs() < 0.03, "rate {rate} too far from 0.2");
    }
}
