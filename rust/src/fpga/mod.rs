//! FPGA resource and power model (Xilinx Zynq-7000, Figs 19-22).
//!
//! The paper implements the same three conv accelerators on a Zynq XC7Z045
//! (ZC706 board) at 200 MHz and reports Vivado "report_utilization" /
//! "report_power" numbers.  Resource mapping is far more deterministic than
//! ASIC synthesis:
//!
//! * every `32 x W` multiply maps to DSP48E1 tiles (a 32-bit multiplier
//!   maps to 3 DSPs — 405 DSPs = 135 taps x 3 for the WS/non-WS designs,
//!   3 DSPs = the single post-pass multiplier for PASM: the paper's
//!   "99 % fewer DSPs");
//! * buffers map to BRAM18K blocks by capacity and partition count (PASM
//!   stores WCI-bit indices instead of W-bit weights: "28 % fewer BRAMs");
//! * the PAS gather fabric maps to LUT/CARRY4 + FF.
//!
//! See [`device`] for part capacity tables (XC7Z045 and the
//! resource-constrained XC7Z020 of the PYNQ-Z1, §5.2) and [`power`] for
//! the per-resource power model at 200 MHz.

pub mod device;
pub mod map;
pub mod power;

pub use device::{Device, Utilization};
pub use map::{map_conv_accel, FpgaDesign};
pub use power::fpga_power;
