//! Map a [`ConvAccel`] onto Zynq FPGA resources.
//!
//! Multiplier lanes go to DSP48E1 tiles; everything else (gather trees,
//! comparators, muxes, control) maps to LUT/FF fabric; buffers map to
//! BRAM18K by partition and capacity.  No ASIC timing-pressure factor is
//! applied — at 200 MHz the paper's designs close timing comfortably,
//! which is exactly why PASM keeps winning on the FPGA at 16 bins while
//! losing in the 1 GHz ASIC (compare Figs 17 and 21).

use crate::accel::conv::{ConvAccel, ConvVariantKind, IMAGE_WIDTH};
use crate::fpga::device::Utilization;
use crate::hw::tech::Tech;
use crate::quant::fixed::ceil_log2;

/// NAND2-equivalents absorbed per LUT6 (empirical Vivado mapping density).
const GATES_PER_LUT: f64 = 6.0;
/// NAND2-equivalents per flip-flop (matches the gate model's DFF cost).
const GATES_PER_FF: f64 = 6.0;
/// BRAM18K capacity in bits.
const BRAM18_BITS: u64 = 18 * 1024;
/// Max read width of one BRAM18 port.
const BRAM18_PORT_BITS: u64 = 36;

/// DSP48E1 tiles needed for an `a x b` multiplier.
///
/// A DSP48E1 multiplies 25 x 18; wider products tile.  32 x 32 maps to
/// 3 DSPs (Vivado composes the fourth partial product in fabric), which is
/// what makes the paper's numbers exact: 135 taps x 3 = 405 DSPs for the
/// WS design, 1 multiplier x 3 = 3 DSPs for PASM.
pub fn dsp_tiles(a: u32, b: u32) -> u64 {
    let tiles = |x: u32, y: u32| ((x as u64).div_ceil(25)) * ((y as u64).div_ceil(18));
    let t = tiles(a, b).min(tiles(b, a));
    if a == 32 && b == 32 {
        3 // fabric-assisted decomposition
    } else {
        t
    }
}

/// BRAM blocks for a buffer of `entries x width` bits split into
/// `partitions` independently addressed banks.
pub fn bram_blocks(entries: u64, width: u64, partitions: u64) -> u64 {
    assert!(partitions >= 1);
    let per_part_entries = entries.div_ceil(partitions);
    let per_part_bits = per_part_entries * width;
    let by_capacity = per_part_bits.div_ceil(BRAM18_BITS);
    let by_port = width.div_ceil(BRAM18_PORT_BITS);
    partitions * by_capacity.max(by_port).max(1)
}

/// A mapped FPGA design.
#[derive(Clone, Debug)]
pub struct FpgaDesign {
    /// Design label (variant/width/bins).
    pub name: String,
    /// Mapped resource usage.
    pub util: Utilization,
    /// Fabric activity estimate (weighted mean of component activities),
    /// feeds the power model.
    pub fabric_activity: f64,
}

/// Map a convolution accelerator onto FPGA resources.
pub fn map_conv_accel(accel: &ConvAccel) -> FpgaDesign {
    let tech = Tech::fpga_200mhz();
    let s = &accel.shape;
    let taps = s.taps() as u64;

    // ---- DSPs: the multiplier instances ----
    let (n_mul, a, b) = accel.multiplier_insts();
    let dsp = n_mul as u64 * dsp_tiles(a, b);

    // ---- BRAM: buffers ----
    // image cache: partitioned by channel for parallel tap access
    let image = bram_blocks((s.in_h * s.in_w) as u64, IMAGE_WIDTH as u64, s.channels as u64);
    // per-variant kernel-side cache, partitioned by kernel position (KY*KX)
    let kparts = (s.kernel_h * s.kernel_w) as u64;
    let kernel_entries = (s.kernels as u64) * taps / kparts;
    // Narrow kernel-side words pack into shared partitions (HLS packs
    // several per BRAM word when width*kparts fits the port budget).
    let packed_parts = |width: u64| kparts.min((kparts * width).div_ceil(32)).max(1);
    let kernel = match accel.variant {
        // dense / decoded weight cache at full W
        ConvVariantKind::Direct | ConvVariantKind::WeightShared => {
            let w = accel.weight_width as u64;
            bram_blocks(kernel_entries, w, packed_parts(w))
        }
        // PASM caches WCI-bit indices instead (packed — the BRAM saving)
        ConvVariantKind::Pasm => {
            let wci = ceil_log2(accel.bins.max(2)).max(1) as u64;
            bram_blocks(kernel_entries, wci, packed_parts(wci))
        }
    };
    // output feature map
    let outfeat = bram_blocks(
        (s.kernels * s.out_pixels()) as u64,
        IMAGE_WIDTH as u64,
        1,
    );
    let bram18 = image + kernel + outfeat;

    // ---- LUT / FF: everything that is not a DSP or BRAM ----
    let mut logicish = 0.0;
    let mut seq = 0.0;
    let mut act_weighted = 0.0;
    for (c, duty) in accel.component_list(&tech) {
        if c.name.contains("mul") {
            continue; // multiplier lanes live in DSPs
        }
        let mut comb = c.gates.logic + c.gates.inverter + c.gates.buffer;
        if c.name.contains("gather_tree") {
            // the ASIC wiring-congestion overhead does not cost LUTs:
            // FPGA routing is prefabricated
            comb /= crate::accel::conv::TREE_WIRING_OVERHEAD;
        }
        logicish += comb;
        seq += c.gates.sequential;
        act_weighted += comb * c.activity * duty;
    }
    let luts = (logicish / GATES_PER_LUT).ceil() as u64;
    let ffs = (seq / GATES_PER_FF).ceil() as u64;
    let fabric_activity = if logicish > 0.0 { act_weighted / logicish } else { 0.0 };

    FpgaDesign {
        name: format!("{:?}-{}b-{}bins", accel.variant, accel.weight_width, accel.bins),
        util: Utilization { luts, ffs, bram18, dsp },
        fabric_activity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::conv::{ConvAccel, ConvVariantKind};

    #[test]
    fn dsp_tile_table() {
        assert_eq!(dsp_tiles(18, 18), 1);
        assert_eq!(dsp_tiles(25, 18), 1);
        assert_eq!(dsp_tiles(32, 32), 3); // paper: 3 DSPs per 32-bit mul
        assert_eq!(dsp_tiles(32, 8), 2);
        assert_eq!(dsp_tiles(8, 8), 1);
    }

    #[test]
    fn paper_405_vs_3_dsps() {
        // §5.2: WS/non-WS use 405 DSPs; PASM uses 3 — "99% fewer DSPs"
        let ws = map_conv_accel(&ConvAccel::paper(ConvVariantKind::WeightShared, 16, 32));
        let direct = map_conv_accel(&ConvAccel::paper(ConvVariantKind::Direct, 16, 32));
        let pasm = map_conv_accel(&ConvAccel::paper(ConvVariantKind::Pasm, 16, 32));
        assert_eq!(ws.util.dsp, 405);
        assert_eq!(direct.util.dsp, 405);
        assert_eq!(pasm.util.dsp, 3);
        let saving = 1.0 - pasm.util.dsp as f64 / ws.util.dsp as f64;
        assert!(saving > 0.99);
    }

    #[test]
    fn pasm_fewer_brams_at_32bit() {
        // §5.2: PASM uses ~28% fewer BRAMs at 32-bit kernels
        for bins in [4usize, 8, 16] {
            let ws = map_conv_accel(&ConvAccel::paper(ConvVariantKind::WeightShared, bins, 32));
            let pasm = map_conv_accel(&ConvAccel::paper(ConvVariantKind::Pasm, bins, 32));
            let saving = 1.0 - pasm.util.bram18 as f64 / ws.util.bram18 as f64;
            assert!(
                saving > 0.15 && saving < 0.45,
                "bins {bins}: bram saving {saving} ({} vs {})",
                pasm.util.bram18,
                ws.util.bram18
            );
        }
    }

    #[test]
    fn eight_bit_brams_similar() {
        // §5.2: at 8-bit kernels PASM uses about the same number of BRAMs
        let ws = map_conv_accel(&ConvAccel::paper(ConvVariantKind::WeightShared, 8, 8));
        let pasm = map_conv_accel(&ConvAccel::paper(ConvVariantKind::Pasm, 8, 8));
        let diff = (ws.util.bram18 as i64 - pasm.util.bram18 as i64).abs();
        assert!(diff <= 3, "{} vs {}", ws.util.bram18, pasm.util.bram18);
    }

    #[test]
    fn ws_overflows_pynq_pasm_fits() {
        // §5.2: the XC7Z020 (220 DSPs) cannot host the WS design (405
        // DSPs); the 4-bin PASM fits the whole part
        let z20 = crate::fpga::Device::xc7z020();
        let ws = map_conv_accel(&ConvAccel::paper(ConvVariantKind::WeightShared, 4, 32));
        let pasm = map_conv_accel(&ConvAccel::paper(ConvVariantKind::Pasm, 4, 32));
        assert!(!ws.util.fits(&z20));
        assert!(pasm.util.fits(&z20), "pasm util {:?}", pasm.util);
    }

    #[test]
    fn pasm_luts_grow_with_bins() {
        let l = |bins| {
            map_conv_accel(&ConvAccel::paper(ConvVariantKind::Pasm, bins, 32)).util.luts
        };
        assert!(l(4) < l(8) && l(8) < l(16));
    }

    #[test]
    fn bram_block_arithmetic() {
        assert_eq!(bram_blocks(512, 32, 1), 1);
        assert_eq!(bram_blocks(1024, 36, 1), 2);
        assert_eq!(bram_blocks(100, 8, 4), 4); // partition-bound
        assert_eq!(bram_blocks(10, 72, 1), 2); // port-width-bound
    }
}
