//! FPGA power model at 200 MHz (Vivado "report_power" analogue).
//!
//! Per-resource dynamic power constants are representative of 7-series
//! characterization at 200 MHz, scaled by activity; static power comes from
//! the device table.  Calibrated once against the paper's Fig 19 headline
//! (PASM 64 % less total power at 4-bin/32-bit) and reused for Figs 20-22.

use crate::fpga::device::Device;
use crate::fpga::map::FpgaDesign;

/// Dynamic power per fully-active resource at 200 MHz (W).
const P_DSP_W: f64 = 2.0e-3;
const P_BRAM18_W: f64 = 3.0e-3;
const P_LUT_W: f64 = 10.0e-6;
const P_FF_W: f64 = 2.0e-6;

/// Default activity for DSP/BRAM when streaming (fraction of cycles).
const DSP_ACTIVITY: f64 = 0.8;
const BRAM_ACTIVITY: f64 = 0.6;
const FF_ACTIVITY: f64 = 0.25;

/// FPGA power report (W).
#[derive(Clone, Copy, Debug, Default)]
pub struct FpgaPower {
    /// Device static power.
    pub static_w: f64,
    /// Activity-weighted dynamic power.
    pub dynamic_w: f64,
}

impl FpgaPower {
    /// Static + dynamic power (W).
    pub fn total_w(&self) -> f64 {
        self.static_w + self.dynamic_w
    }
}

/// Evaluate a mapped design's power on a device at 200 MHz.
pub fn fpga_power(design: &FpgaDesign, device: &Device) -> FpgaPower {
    let u = &design.util;
    let dynamic = u.dsp as f64 * P_DSP_W * DSP_ACTIVITY
        + u.bram18 as f64 * P_BRAM18_W * BRAM_ACTIVITY
        + u.luts as f64 * P_LUT_W * design.fabric_activity.max(0.05)
        + u.ffs as f64 * P_FF_W * FF_ACTIVITY;
    FpgaPower { static_w: device.static_power_w, dynamic_w: dynamic }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::conv::{ConvAccel, ConvVariantKind};
    use crate::fpga::map::map_conv_accel;

    fn power_saving(bins: usize, ww: u32) -> f64 {
        let dev = Device::xc7z045();
        let ws = fpga_power(
            &map_conv_accel(&ConvAccel::paper(ConvVariantKind::WeightShared, bins, ww)),
            &dev,
        );
        let pasm = fpga_power(
            &map_conv_accel(&ConvAccel::paper(ConvVariantKind::Pasm, bins, ww)),
            &dev,
        );
        1.0 - pasm.total_w() / ws.total_w()
    }

    #[test]
    fn paper_fig19_4bin_32bit() {
        // paper: PASM consumes ~64% less total power (4-bin, 32-bit)
        let s = power_saving(4, 32);
        assert!(s > 0.45 && s < 0.75, "saving {s}");
    }

    #[test]
    fn savings_decrease_with_bins_but_stay_positive_at_16() {
        // Figs 19-21: 64% -> 41.6% -> 18%: the FPGA at 200 MHz never flips
        let s4 = power_saving(4, 32);
        let s8 = power_saving(8, 32);
        let s16 = power_saving(16, 32);
        assert!(s4 > s8 && s8 > s16, "{s4} {s8} {s16}");
        assert!(s16 > 0.0, "16-bin saving {s16}");
    }

    #[test]
    fn eight_bit_eight_bin_positive() {
        // Fig 22: 8-bit kernels, 8 bins -> PASM still saves power
        let s = power_saving(8, 8);
        assert!(s > 0.0, "saving {s}");
    }

    #[test]
    fn dsp_power_dominates_ws() {
        let dev = Device::xc7z045();
        let ws = map_conv_accel(&ConvAccel::paper(ConvVariantKind::WeightShared, 4, 32));
        let p = fpga_power(&ws, &dev);
        let dsp_part = ws.util.dsp as f64 * P_DSP_W * DSP_ACTIVITY;
        assert!(dsp_part > 0.5 * p.dynamic_w);
    }
}
