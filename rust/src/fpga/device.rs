//! Zynq-7000 device capacity tables and utilization checking.

/// FPGA resource vector (the columns of Vivado "report_utilization").
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Utilization {
    /// Lookup tables.
    pub luts: u64,
    /// Flip-flops.
    pub ffs: u64,
    /// 18 Kb BRAM blocks.
    pub bram18: u64,
    /// DSP48 slices.
    pub dsp: u64,
}

impl Utilization {
    /// Whether this usage fits within a device's capacity.
    pub fn fits(&self, device: &Device) -> bool {
        self.luts <= device.luts
            && self.ffs <= device.ffs
            && self.bram18 <= device.bram18
            && self.dsp <= device.dsp
    }

    /// Per-resource utilization fractions against a device.
    pub fn fractions(&self, device: &Device) -> [(&'static str, f64); 4] {
        [
            ("LUT", self.luts as f64 / device.luts as f64),
            ("FF", self.ffs as f64 / device.ffs as f64),
            ("BRAM18", self.bram18 as f64 / device.bram18 as f64),
            ("DSP", self.dsp as f64 / device.dsp as f64),
        ]
    }
}

impl std::ops::Add for Utilization {
    type Output = Utilization;
    fn add(self, o: Utilization) -> Utilization {
        Utilization {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            bram18: self.bram18 + o.bram18,
            dsp: self.dsp + o.dsp,
        }
    }
}

/// A Xilinx 7-series part.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    /// Part name (e.g. "XC7Z045").
    pub name: &'static str,
    /// LUT capacity.
    pub luts: u64,
    /// Flip-flop capacity.
    pub ffs: u64,
    /// 18 Kb BRAM blocks (a RAMB36 counts as two).
    pub bram18: u64,
    /// DSP48 slice capacity.
    pub dsp: u64,
    /// Static power of the part at typical conditions (W).
    pub static_power_w: f64,
}

impl Device {
    /// Zynq XC7Z045 (ZC706 board) — the paper's main FPGA target.
    pub fn xc7z045() -> Device {
        Device {
            name: "XC7Z045",
            luts: 218_600,
            ffs: 437_200,
            bram18: 1090,
            dsp: 900,
            static_power_w: 0.25,
        }
    }

    /// Zynq XC7Z020 (PYNQ-Z1 board) — the resource-constrained part of
    /// §5.2: 220 DSPs, which the 405-DSP WS design over-utilizes.
    pub fn xc7z020() -> Device {
        Device {
            name: "XC7Z020",
            luts: 53_200,
            ffs: 106_400,
            bram18: 280,
            dsp: 220,
            static_power_w: 0.12,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dsp_capacities() {
        // §5.2: the XC7Z020 has 220 DSPs; 405 > 220 (WS doesn't fit),
        // 3 <= 220 (PASM fits)
        let z20 = Device::xc7z020();
        assert_eq!(z20.dsp, 220);
        assert!(Utilization { dsp: 405, ..Default::default() }.fits(&z20) == false);
        assert!(Utilization { dsp: 3, ..Default::default() }.fits(&z20));
        assert!(Utilization { dsp: 405, ..Default::default() }.fits(&Device::xc7z045()));
    }

    #[test]
    fn add_and_fractions() {
        let a = Utilization { luts: 100, ffs: 200, bram18: 2, dsp: 3 };
        let b = Utilization { luts: 50, ffs: 100, bram18: 1, dsp: 0 };
        let s = a + b;
        assert_eq!(s.luts, 150);
        assert_eq!(s.dsp, 3);
        let f = s.fractions(&Device::xc7z020());
        assert!(f[3].1 > 0.0 && f[3].1 < 1.0);
    }
}
