//! §2.4 standalone units: weight-shared **16-MAC** vs **16-PAS-4-MAC**.
//!
//! Streaming micro-architecture (the paper's Verilog designs, synthesized
//! at 100 MHz): each of the 16 lanes consumes one `(image, weight-index)`
//! pair per cycle.
//!
//! * **16-MAC lane**: weight register file (`B x W`, one read port indexed
//!   by the dictionary index — Fig 3), `W x W` multiplier, accumulator
//!   adder + register.
//! * **16-PAS lane**: `B x W` accumulator register file (write port for the
//!   read-modify-write, read port for the post-pass drain — Table 1's two
//!   file ports), bin-select decode, one `W`-bit adder.
//! * **shared post-pass**: `postpass` MAC units (4 in the paper), each a
//!   `W x W` multiplier + accumulator, fed from the PAS lanes through
//!   4:1 muxes, reading a single shared codebook register file.
//!
//! Reproduces Figs 7-10 (gate-count and power sweeps over W and B).

use crate::hw::gates::{
    adder_for_budget, decoder, mux, multiplier, regfile, register, Component,
    GateBreakdown,
};
use crate::hw::power::{PowerBreakdown, PowerModel};
use crate::hw::tech::Tech;
use crate::hw::timing::{timing_area_factor, PathDelay};
use crate::quant::fixed::ceil_log2;

/// Which §2.4 unit to model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnitKind {
    /// 16 weight-shared MAC units (the baseline).
    Mac16,
    /// 16 PAS units + 4 shared post-pass MACs (the proposal).
    Pas16Mac4,
}

/// A sized standalone unit.
#[derive(Clone, Copy, Debug)]
pub struct StandaloneUnit {
    /// MAC16 baseline or PAS16-MAC4 proposal.
    pub kind: UnitKind,
    /// Data bit width W (paper sweeps 4, 8, 16, 32).
    pub width: u32,
    /// Weight bins B (paper sweeps 4, 16, 64, 256).
    pub bins: usize,
    /// Parallel lanes (16 in the paper).
    pub lanes: usize,
    /// Shared post-pass MACs (4 in the paper; Mac16 ignores this).
    pub postpass: usize,
}

impl StandaloneUnit {
    /// The paper's 16-MAC baseline at a given width and bin count.
    pub fn mac16(width: u32, bins: usize) -> Self {
        StandaloneUnit { kind: UnitKind::Mac16, width, bins, lanes: 16, postpass: 0 }
    }

    /// The paper's 16-PAS-4-MAC proposal at a given width and bin count.
    pub fn pas16mac4(width: u32, bins: usize) -> Self {
        StandaloneUnit { kind: UnitKind::Pas16Mac4, width, bins, lanes: 16, postpass: 4 }
    }

    /// Multiplier synthesis overhead vs the idealized array structure:
    /// Genus maps multipliers through Booth recoding / compressor trees
    /// whose NAND2-normalized report runs ~2x the textbook array count
    /// (single calibration constant, fixed across all experiments;
    /// fitted against the paper's Fig 7 W=32/B=16 headline).
    const MUL_SYNTH_OVERHEAD: f64 = 2.2;

    fn mul(&self) -> Component {
        let mut m = multiplier(self.width, self.width);
        m.gates = m.gates * Self::MUL_SYNTH_OVERHEAD;
        m
    }

    /// Components of the design with duty factors (fraction of cycles
    /// active during streaming).
    fn components(&self, tech: &Tech) -> Vec<(Component, f64)> {
        let w = self.width;
        let b = self.bins;
        let levels_budget =
            (tech.period_s() * 0.92 - tech.ff_overhead_s) / tech.gate_delay_s;
        let mut out: Vec<(Component, f64)> = Vec::new();

        match self.kind {
            UnitKind::Mac16 => {
                for _ in 0..self.lanes {
                    // weight dictionary: B x W, read through the bin index
                    out.push((regfile(b, w, 1, 1), 1.0));
                    // W x W multiplier (the unit PASM removes)
                    out.push((self.mul(), 1.0));
                    // accumulator adder + register (Table 1 sizes at W)
                    out.push((adder_for_budget(w, levels_budget), 1.0));
                    out.push((register(w), 1.0));
                    // input operand registers
                    out.push((register(w), 1.0)); // image in
                    out.push((register(ceil_log2(b.max(2)).max(1)), 1.0)); // index in
                }
            }
            UnitKind::Pas16Mac4 => {
                let idx_bits = ceil_log2(b.max(2)).max(1);
                for _ in 0..self.lanes {
                    // B accumulator bins: storage + write decode (RMW port)
                    // + read port for the post-pass drain (2 ports, Table 1)
                    out.push((regfile(b, w, 1, 1), 1.0));
                    out.push((decoder(idx_bits), 1.0));
                    // the single accumulate adder per PAS
                    out.push((adder_for_budget(w, levels_budget), 1.0));
                    // input operand registers
                    out.push((register(w), 1.0));
                    out.push((register(idx_bits), 1.0));
                }
                // shared post-pass: codebook regfile + `postpass` MACs
                out.push((regfile(b, w, self.postpass.max(1), 1), 1.0));
                let drain_duty =
                    (self.lanes as f64 * b as f64) / self.stream_cycles(1024) as f64;
                for _ in 0..self.postpass {
                    out.push((self.mul(), drain_duty.min(1.0)));
                    out.push((adder_for_budget(w, levels_budget), drain_duty.min(1.0)));
                    out.push((register(w), 1.0));
                    // 4:1 mux from the PAS lanes it serves
                    out.push((
                        mux(self.lanes / self.postpass.max(1), w),
                        drain_duty.min(1.0),
                    ));
                }
            }
        }
        out
    }

    /// Critical path of the design (the loop-carried accumulate recurrence).
    pub fn critical_path(&self, tech: &Tech) -> PathDelay {
        let levels_budget =
            (tech.period_s() * 0.92 - tech.ff_overhead_s) / tech.gate_delay_s;
        let adder = adder_for_budget(self.width, levels_budget);
        match self.kind {
            UnitKind::Mac16 => {
                // regfile read mux -> (pipelined) multiplier last stage ->
                // accumulator adder -> register
                PathDelay::new()
                    .through(&mux(self.bins, self.width))
                    .plus_levels(levels_budget.min(self.mul().depth_levels / 2.0))
                    .through(&adder)
            }
            UnitKind::Pas16Mac4 => {
                // bin read mux -> adder -> write-back broadcast to B bins
                PathDelay::new()
                    .through(&mux(self.bins, self.width))
                    .through(&adder)
                    .broadcast(self.bins as f64)
            }
        }
    }

    /// Gate breakdown after timing-pressure scaling.
    pub fn gates(&self, tech: &Tech) -> GateBreakdown {
        let factor = timing_area_factor(self.critical_path(tech).utilization(tech));
        self.components(tech)
            .iter()
            .fold(GateBreakdown::default(), |acc, (c, _)| acc + c.gates)
            .scale_combinational(factor)
    }

    /// Power under `tech`, with default activities and duty cycles.
    pub fn power(&self, tech: &Tech) -> PowerBreakdown {
        let factor = timing_area_factor(self.critical_path(tech).utilization(tech));
        let mut pm = PowerModel::new();
        for (c, duty) in self.components(tech) {
            pm.add_scaled(&c, c.activity, duty, factor);
        }
        pm.power(tech)
    }

    /// Cycles to process `n_pairs` input pairs per lane (§2.2's example:
    /// 1024 pairs -> 1024 for 16-MAC, 1024 + 4*16 = 1088 for 16-PAS-4-MAC).
    pub fn stream_cycles(&self, n_pairs: u64) -> u64 {
        match self.kind {
            UnitKind::Mac16 => n_pairs,
            UnitKind::Pas16Mac4 => {
                let groups = (self.lanes / self.postpass.max(1)) as u64;
                n_pairs + groups * self.bins as u64
            }
        }
    }

    /// Full report at a tech point.
    pub fn report(&self, tech: &Tech) -> StandaloneReport {
        StandaloneReport {
            unit: *self,
            gates: self.gates(tech),
            power: self.power(tech),
            cycles_1024: self.stream_cycles(1024),
        }
    }
}

/// Evaluation record for one standalone configuration.
#[derive(Clone, Copy, Debug)]
pub struct StandaloneReport {
    /// The configuration evaluated.
    pub unit: StandaloneUnit,
    /// NAND2-normalized gate breakdown.
    pub gates: GateBreakdown,
    /// Power at the evaluation tech point.
    pub power: PowerBreakdown,
    /// Exact cycles to stream 1024 (image, index) pairs (paper SS2.2).
    pub cycles_1024: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cycle_example() {
        // §2.2: 1024 pairs, B=16: MAC 1024 cycles, PASM 1024 + 4*16 = 1088
        assert_eq!(StandaloneUnit::mac16(32, 16).stream_cycles(1024), 1024);
        assert_eq!(StandaloneUnit::pas16mac4(32, 16).stream_cycles(1024), 1088);
    }

    #[test]
    fn pasm_wins_at_w32_b16() {
        // Fig 7/8 headline: W=32, B=16 -> PASM saves a large fraction of
        // gates and power (paper: 66% gates, 70% power)
        let t = Tech::asic_100mhz();
        let mac = StandaloneUnit::mac16(32, 16).report(&t);
        let pasm = StandaloneUnit::pas16mac4(32, 16).report(&t);
        let gate_saving = 1.0 - pasm.gates.total() / mac.gates.total();
        let power_saving = 1.0 - pasm.power.total_w() / mac.power.total_w();
        assert!(
            gate_saving > 0.5 && gate_saving < 0.8,
            "gate saving {gate_saving}"
        );
        assert!(
            power_saving > 0.5 && power_saving < 0.85,
            "power saving {power_saving}"
        );
    }

    #[test]
    fn savings_grow_with_width() {
        // Fig 7/8: the PASM advantage grows with W (multiplier is O(W^2))
        let t = Tech::asic_100mhz();
        let saving = |w: u32| {
            let mac = StandaloneUnit::mac16(w, 16).report(&t);
            let pasm = StandaloneUnit::pas16mac4(w, 16).report(&t);
            1.0 - pasm.gates.total() / mac.gates.total()
        };
        assert!(saving(8) < saving(16));
        assert!(saving(16) < saving(32));
    }

    #[test]
    fn pasm_loses_at_b256() {
        // Fig 9: "at B=256, PASM registers and buffers are less efficient
        // than the MAC" — sequential gates flip in favour of the MAC
        let t = Tech::asic_100mhz();
        let mac = StandaloneUnit::mac16(32, 256).report(&t);
        let pasm = StandaloneUnit::pas16mac4(32, 256).report(&t);
        assert!(
            pasm.gates.sequential > mac.gates.sequential,
            "pasm seq {} vs mac seq {}",
            pasm.gates.sequential,
            mac.gates.sequential
        );
    }

    #[test]
    fn savings_shrink_with_bins() {
        let t = Tech::asic_100mhz();
        let saving = |b: usize| {
            let mac = StandaloneUnit::mac16(32, b).report(&t);
            let pasm = StandaloneUnit::pas16mac4(32, b).report(&t);
            1.0 - pasm.gates.total() / mac.gates.total()
        };
        assert!(saving(4) > saving(64));
        assert!(saving(64) > saving(256));
    }
}
