//! HLS directive configuration (the `#pragma HLS` knobs of Fig 13).

/// The synthesis-directive configuration the paper explores (§4, §5.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HlsConfig {
    /// `PIPELINE II=1 rewind` on the output loop (one output per slot).
    pub pipeline_ii1: bool,
    /// Fully unroll the tap loops (c, ky, kx) inside the pipelined region.
    pub unroll_taps: bool,
    /// `ARRAY_PARTITION variable=imageBin complete` — bins in registers,
    /// not BRAM (enables parallel PAS accumulation).
    pub partition_bins: bool,
    /// `ALLOCATION instances=mul limit=N` — post-pass multiplier budget.
    pub postpass_muls: usize,
}

impl Default for HlsConfig {
    /// The paper's configuration: II=1, full unroll, full partition, one
    /// post-pass multiplier (Fig 13 lines 2-3, 7, 10).
    fn default() -> Self {
        HlsConfig {
            pipeline_ii1: true,
            unroll_taps: true,
            partition_bins: true,
            postpass_muls: 1,
        }
    }
}

impl HlsConfig {
    /// A latency-relaxed variant (§5.1: "Latency can be further reduced by
    /// relaxing the ALLOCATION directive" — more multipliers, more area).
    pub fn with_postpass_muls(mut self, n: usize) -> Self {
        assert!(n >= 1);
        self.postpass_muls = n;
        self
    }

    /// The no-unroll fallback the paper suggests for large B (§5.1/§5.2:
    /// "reduce pipelining and unrolling of the levels of the inner four of
    /// the for loops").
    pub fn sequential() -> Self {
        HlsConfig {
            pipeline_ii1: true,
            unroll_taps: false,
            partition_bins: true,
            postpass_muls: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_fig13() {
        let h = HlsConfig::default();
        assert!(h.pipeline_ii1 && h.unroll_taps && h.partition_bins);
        assert_eq!(h.postpass_muls, 1);
    }

    #[test]
    fn relaxed_allocation() {
        let h = HlsConfig::default().with_postpass_muls(4);
        assert_eq!(h.postpass_muls, 4);
    }
}
