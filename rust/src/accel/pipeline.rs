//! Retiming helper: pipeline a combinational component to meet the clock.
//!
//! If a component's natural depth does not fit the period, synthesis (or
//! HLS scheduling) cuts it into stages separated by pipeline registers.
//! Area cost: `(stages - 1)` registers of the datapath width; benefit: the
//! per-stage path utilization drops by ~`stages`.  This is the trade the
//! paper quantifies in §4 ("reduces the latency cycles ... by 92% at the
//! expense of increasing the flip flop count by 97%").

use crate::hw::gates::{register, Component, GateBreakdown};
use crate::hw::tech::Tech;
use crate::hw::timing::PathDelay;

/// A component after retiming: original gates + pipeline registers, the
/// resulting per-stage path, and the stage count (= added latency cycles).
#[derive(Clone, Debug)]
pub struct Pipelined {
    /// Gate cost including the added pipeline registers.
    pub gates: GateBreakdown,
    /// Combinational path of one stage.
    pub stage_path: PathDelay,
    /// Stage count (equals the added latency in cycles).
    pub stages: u32,
}

/// Fraction of the period available to logic (margin for clock skew,
/// uncertainty — the paper constrains a 0.01 ns transition at 1 GHz).
const PERIOD_MARGIN: f64 = 0.92;

/// Retime `c` (datapath `width_bits` wide) for `tech`'s clock.
pub fn pipeline(c: &Component, width_bits: u32, tech: &Tech) -> Pipelined {
    let budget_s = tech.period_s() * PERIOD_MARGIN - tech.ff_overhead_s;
    let natural_s = c.depth_levels * tech.gate_delay_s
        + c.max_fanout * tech.fanout_delay_per_sink_s;
    let stages = (natural_s / budget_s).ceil().max(1.0) as u32;

    let mut gates = c.gates;
    if stages > 1 {
        gates += register(width_bits).gates * (stages - 1) as f64;
    }
    let stage_path = PathDelay {
        levels: c.depth_levels / stages as f64,
        fanout_sinks: c.max_fanout / stages as f64,
        ff_stages: 1.0,
    };
    Pipelined { gates, stage_path, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gates::multiplier;

    #[test]
    fn no_stages_at_relaxed_clock() {
        let t = Tech::asic_100mhz();
        let p = pipeline(&multiplier(32, 32), 64, &t);
        assert_eq!(p.stages, 1);
        assert_eq!(p.gates.sequential, 0.0);
    }

    #[test]
    fn multiplier_needs_stages_at_1ghz() {
        let t = Tech::asic_1ghz();
        let p = pipeline(&multiplier(32, 32), 64, &t);
        assert!(p.stages >= 2, "stages {}", p.stages);
        assert!(p.gates.sequential > 0.0); // pipeline registers appeared
        assert!(p.stage_path.utilization(&t) <= 1.05);
    }

    #[test]
    fn latency_area_tradeoff() {
        // deeper pipeline -> more sequential gates, shorter stage path
        let t = Tech::asic_1ghz();
        let p8 = pipeline(&multiplier(8, 8), 16, &t);
        let p32 = pipeline(&multiplier(32, 32), 64, &t);
        assert!(p32.stages > p8.stages);
        assert!(p32.gates.sequential > p8.gates.sequential);
    }
}
