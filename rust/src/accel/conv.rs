//! §3-4 CNN convolution-layer accelerators (three variants).
//!
//! Structure follows the paper's HLS design (Fig 13): the tap loops
//! (c, ky, kx) are fully unrolled inside an II=1 pipeline over
//! `(pixel, m)` slots, `imageBin` is completely partitioned into
//! registers, and the post-pass multiplier count is capped by ALLOCATION.
//! The FPGA DSP counts confirm the unroll: 405 DSPs = 135 taps x 3 DSPs
//! per 32-bit multiplier for the WS/non-WS variants, 3 DSPs = the single
//! post-pass multiplier for PASM.
//!
//! * **Direct** (non-weight-shared): per tap a `32 x W` multiplier fed from
//!   a dense weight cache, plus a taps-wide adder tree.
//! * **WeightShared**: per tap a codebook read mux (`B:1 x W`) in front of
//!   the same multiplier array.
//! * **Pasm**: per (tap, bin) a comparator+mask, per bin a taps-wide
//!   gather adder tree, and `postpass_muls` shared multipliers.  The
//!   per-tap image broadcast to all `B` gather trees is the high-fanout
//!   net that breaks down at 1 GHz for large B (paper Fig 17).
//!
//! ### Calibration
//! Constants marked `CAL:` below are fitted once against the paper's §5.1
//! ASIC series (4/8/16-bin, 32-bit: -47.8 % / -8.1 % / worse; Fig 14
//! latency +8.5 %..+12.75 %) and then reused unchanged for the 8-bit
//! series, the FPGA mapping, and every sweep.  See EXPERIMENTS.md for the
//! paper-vs-model residuals.

use crate::accel::hls::HlsConfig;
use crate::accel::pipeline::pipeline;
use crate::hw::gates::{
    adder_tree, and_mask, comparator, fsm, multiplier, mux, regfile, register, register_en,
    Component, GateBreakdown,
};
use crate::hw::power::{PowerBreakdown, PowerModel};
use crate::hw::tech::Tech;
use crate::hw::timing::{timing_area_factor, PathDelay};
use crate::quant::fixed::ceil_log2;
use crate::tensor::ConvShape;

/// Image datapath width (the paper keeps images at 32-bit INT throughout).
pub const IMAGE_WIDTH: u32 = 32;

/// Which accelerator variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvVariantKind {
    /// Non-weight-shared baseline (dense weights).
    Direct,
    /// Weight-shared MAC baseline (dictionary decode + MAC).
    WeightShared,
    /// Weight-shared with PASM (the paper's proposal).
    Pasm,
}

/// A sized convolution-layer accelerator.
#[derive(Clone, Debug)]
pub struct ConvAccel {
    /// Which MAC architecture the accelerator uses.
    pub variant: ConvVariantKind,
    /// The conv layer the accelerator is sized for.
    pub shape: ConvShape,
    /// Weight bins B (ignored by `Direct`).
    pub bins: usize,
    /// Kernel (weight) bit width W: the paper sweeps 8 and 32.
    pub weight_width: u32,
    /// HLS directive knobs (unrolling, pipelining).
    pub hls: HlsConfig,
    /// Back the image cache with an SRAM macro instead of registers (the
    /// paper's footnote-1 what-if; the FreePDK45 flow could not synthesize
    /// SRAM, capping the tile at C=15).
    pub sram_cache: bool,
}

// ---------------------------------------------------------------------------
// CAL: calibration constants (single global fit, see module docs)
// ---------------------------------------------------------------------------

/// CAL: multiplier synthesis overhead vs the textbook array structure
/// (Booth recoding + compressor wiring in the Genus report).
const MUL_SYNTH_OVERHEAD: f64 = 1.7;

/// CAL: wiring/placement overhead of the B-way gather trees (the paper's
/// PASM netlists route every tap to every bin's tree; congestion dominates
/// the placed area of the gather fabric).  ASIC-only: FPGA routing fabric
/// is prefabricated, so `fpga::map` divides this back out of the trees.
pub(crate) const TREE_WIRING_OVERHEAD: f64 = 3.3;

/// CAL: fanout sinks per broadcast image bit into the gather trees
/// (drives the timing-pressure utilization growth with B — tips the
/// 16-bin/32-bit design past the 1 GHz period, Fig 17).
const GATHER_FANOUT_PER_BIT: f64 = 0.05;

/// CAL: Fig 14 latency fit — B-independent PASM pipeline overhead (cycles)
/// and the post-pass overlap divisor (outputs*B/K extra cycles).
const PASM_LATENCY_FIXED: f64 = 2.0;
const PASM_POSTPASS_OVERLAP: f64 = 180.0;

impl ConvAccel {
    /// An accelerator for `shape` with default HLS knobs and no SRAM cache.
    pub fn new(
        variant: ConvVariantKind,
        shape: ConvShape,
        bins: usize,
        weight_width: u32,
    ) -> Self {
        ConvAccel {
            variant,
            shape,
            bins,
            weight_width,
            hls: HlsConfig::default(),
            sram_cache: false,
        }
    }

    /// The paper's §4 tile at a given variant/bins/width.
    pub fn paper(variant: ConvVariantKind, bins: usize, weight_width: u32) -> Self {
        Self::new(variant, ConvShape::paper_tile(), bins, weight_width)
    }

    fn idx_bits(&self) -> u32 {
        ceil_log2(self.bins.max(2)).max(1)
    }

    fn taps(&self) -> usize {
        self.shape.taps()
    }

    fn outputs(&self) -> usize {
        self.shape.kernels * self.shape.out_pixels()
    }

    fn mul(&self, a: u32, b: u32) -> Component {
        let mut m = multiplier(a, b);
        m.gates = m.gates * MUL_SYNTH_OVERHEAD;
        m
    }

    /// Buffers common to all three variants (image cache, output feature
    /// registers, bias, control).
    fn shared_components(&self) -> Vec<(Component, f64)> {
        let s = &self.shape;
        let image_bits = (s.channels * s.in_h * s.in_w) as u32;
        let out_entries = s.kernels * s.out_pixels();
        let image_cache = if self.sram_cache {
            // footnote-1 what-if: SRAM macro, dual-port, ~1 access/cycle
            crate::hw::sram::SramMacro::new((image_bits as u64) * IMAGE_WIDTH as u64, 2)
                .component("image_cache_sram", 1.0)
        } else {
            // image cache in registers (§4: "kept to a small tile ... to
            // allow its implementation in a register file")
            register(image_bits * IMAGE_WIDTH)
        };
        vec![
            (image_cache, 0.3),
            // output feature map register file
            (regfile(out_entries, IMAGE_WIDTH, 1, 1), 0.5),
            // bias registers + bias adders (not shared, §4)
            (register((s.kernels as u32) * self.weight_width), 0.2),
            (crate::hw::gates::adder_cla(IMAGE_WIDTH), 0.5),
            // ReLU (sign-select per output)
            (and_mask(IMAGE_WIDTH), 0.5),
            (fsm(12), 1.0),
        ]
    }

    /// Per-variant datapath components with duty factors, plus the
    /// dominant combinational path for timing pressure.
    fn datapath(&self, tech: &Tech) -> (Vec<(Component, f64)>, PathDelay) {
        let taps = self.taps() as f64;
        let ww = self.weight_width;
        let iw = IMAGE_WIDTH;
        let m = self.shape.kernels;
        let mut out: Vec<(Component, f64)> = Vec::new();

        // How many taps execute concurrently (full unroll vs sequential).
        let par = if self.hls.unroll_taps { self.taps() } else { 1 };

        match self.variant {
            ConvVariantKind::Direct => {
                // dense weight cache: per tap an M-entry regfile (selects
                // the kernel plane for the current pipeline slot)
                let wregs = regfile(m, ww, 1, 1).gates * par as f64;
                out.push((component_from(wregs, "weight_cache", 0.10, 0.0), 1.0));
                for _ in 0..par {
                    let p = pipeline(&self.mul(iw, ww), iw + ww, tech);
                    out.push((component_from(p.gates, "mul_lane", 0.28, 0.0), 1.0));
                }
                let tree = pipeline(&adder_tree(par.max(2), iw), iw, tech);
                out.push((component_from(tree.gates, "sum_tree", 0.20, 0.0), 1.0));
                let staged = pipeline(&self.mul(iw, ww), iw + ww, tech);
                return (out, staged.stage_path);
            }
            ConvVariantKind::WeightShared => {
                for _ in 0..par {
                    // codebook read mux (the Fig 3 indirection)
                    out.push((mux(self.bins, ww), 1.0));
                    // bin-index cache per tap (M entries)
                    out.push((regfile(m, self.idx_bits(), 1, 1), 0.3));
                    let p = pipeline(&self.mul(iw, ww), iw + ww, tech);
                    out.push((component_from(p.gates, "mul_lane", 0.28, 0.0), 1.0));
                }
                // shared codebook registers (broadcast to all lanes)
                out.push((register_en((self.bins as u32) * ww), 0.1));
                let tree = pipeline(&adder_tree(par.max(2), iw), iw, tech);
                out.push((component_from(tree.gates, "sum_tree", 0.20, 0.0), 1.0));
                let staged = pipeline(&self.mul(iw, ww), iw + ww, tech);
                let path = staged
                    .stage_path
                    .plus_levels(mux(self.bins, ww).depth_levels * 0.5);
                return (out, path);
            }
            ConvVariantKind::Pasm => {
                let b = self.bins;
                // per (tap, bin): comparator + image mask
                for _ in 0..par {
                    out.push((regfile(m, self.idx_bits(), 1, 1), 0.3));
                }
                if self.hls.partition_bins {
                    // ARRAY_PARTITION complete: B parallel gather trees
                    let cmp_mask_logic =
                        (comparator(self.idx_bits()).gates + and_mask(iw).gates)
                            * (par as f64 * b as f64);
                    out.push((
                        component_from(cmp_mask_logic, "gather_select", 0.18, 0.0),
                        1.0,
                    ));
                    // per bin: taps-wide gather tree (pipelined), with
                    // wiring overhead — every image value routes to every
                    // tree
                    let mut tree_c = adder_tree(par.max(2), iw);
                    tree_c.gates = tree_c.gates * TREE_WIRING_OVERHEAD;
                    let tree = pipeline(&tree_c, iw, tech);
                    for _ in 0..b {
                        out.push((component_from(tree.gates, "gather_tree", 0.20, 0.0), 1.0));
                    }
                    // bin accumulator registers (partitioned)
                    out.push((register_en((b as u32) * iw), 1.0));
                } else {
                    // §5.3 fallback: imageBin in a (BRAM-like) register
                    // file with one RMW port — tiny area, serialized
                    // accumulation (the latency model pays the II=B price)
                    out.push((regfile(b, iw, 1, 1), 1.0));
                    out.push((crate::hw::gates::adder_cla(iw), 1.0));
                    out.push((crate::hw::gates::decoder(self.idx_bits()), 1.0));
                }
                // post-pass MACs + shared codebook
                let drain_duty = (b as f64
                    / (self.hls.postpass_muls as f64 * taps.max(1.0)))
                .min(1.0);
                for _ in 0..self.hls.postpass_muls {
                    let p = pipeline(&self.mul(iw, ww), iw + ww, tech);
                    out.push((component_from(p.gates, "postpass_mul", 0.28, 0.0), drain_duty));
                    out.push((crate::hw::gates::adder_cla(iw), drain_duty));
                    out.push((register(iw), 1.0));
                }
                out.push((register_en((b as u32) * ww), 0.1));
                let path = if self.hls.partition_bins {
                    // timing: first gather stage = comparator + mask + tree
                    // head, with the per-bit broadcast into all B trees
                    let mut tree_c = adder_tree(par.max(2), iw);
                    tree_c.gates = tree_c.gates * TREE_WIRING_OVERHEAD;
                    let tree = pipeline(&tree_c, iw, tech);
                    PathDelay::new()
                        .through(&comparator(self.idx_bits()))
                        .through(&and_mask(iw))
                        .plus_levels(tree.stage_path.levels)
                        .broadcast(GATHER_FANOUT_PER_BIT * b as f64 * iw as f64)
                } else {
                    // streaming RMW recurrence: bin read mux -> adder ->
                    // write-back (never near the period at these widths)
                    PathDelay::new()
                        .through(&mux(b, iw))
                        .through(&crate::hw::gates::adder_cla(iw))
                        .broadcast(b as f64)
                };
                return (out, path);
            }
        }
    }

    /// Full component list (datapath + shared buffers) with duty factors,
    /// *without* timing-pressure scaling — the FPGA mapper consumes this
    /// (multiplier lanes are identified by name and diverted to DSP48s).
    pub fn component_list(&self, tech: &Tech) -> Vec<(Component, f64)> {
        let (mut dp, _) = self.datapath(tech);
        dp.extend(self.shared_components());
        dp
    }

    /// Number of hardware multipliers in the design and their operand
    /// widths (for DSP mapping): `(count, a_bits, b_bits)`.
    pub fn multiplier_insts(&self) -> (usize, u32, u32) {
        let par = if self.hls.unroll_taps { self.taps() } else { 1 };
        match self.variant {
            ConvVariantKind::Direct | ConvVariantKind::WeightShared => {
                (par, IMAGE_WIDTH, self.weight_width)
            }
            ConvVariantKind::Pasm => (self.hls.postpass_muls, IMAGE_WIDTH, self.weight_width),
        }
    }

    /// Total gate breakdown under `tech` (timing pressure applied to the
    /// variant's dominant path).
    pub fn gates(&self, tech: &Tech) -> GateBreakdown {
        let (dp, path) = self.datapath(tech);
        let factor = timing_area_factor(path.utilization(tech));
        let mut total = GateBreakdown::default();
        for (c, _) in &dp {
            total += c.gates;
        }
        total = total.scale_combinational(factor);
        for (c, _) in self.shared_components() {
            total += c.gates;
        }
        total
    }

    /// Power under `tech` with default activities (override via
    /// [`ConvAccel::power_with_activity`]).
    pub fn power(&self, tech: &Tech) -> PowerBreakdown {
        self.power_with_activity(tech, 1.0)
    }

    /// Power with a measured datapath activity scale from the simulator
    /// (1.0 = the component defaults).
    pub fn power_with_activity(&self, tech: &Tech, activity_scale: f64) -> PowerBreakdown {
        let (dp, path) = self.datapath(tech);
        let factor = timing_area_factor(path.utilization(tech));
        let mut pm = PowerModel::new();
        for (c, duty) in &dp {
            pm.add_scaled(c, (c.activity * activity_scale).min(1.0), *duty, factor);
        }
        for (c, duty) in &self.shared_components() {
            pm.add_scaled(c, (c.activity * activity_scale).min(1.0), *duty, 1.0);
        }
        pm.power(tech)
    }

    /// Path utilization (for reports / the 800 MHz what-if).
    pub fn path_utilization(&self, tech: &Tech) -> f64 {
        self.datapath(tech).1.utilization(tech)
    }

    /// Layer latency in cycles (validated against the cycle simulator).
    ///
    /// All variants pipeline one output per slot after the fill; PASM adds
    /// the post-pass drain (Fig 14: +8.5 %..+12.75 % over WS), reduced by
    /// extra post-pass multipliers (§5.1 ALLOCATION relaxation).
    pub fn latency_cycles(&self) -> u64 {
        self.latency_cycles_exact().ceil() as u64
    }

    /// Unrounded latency (cycles); use this for overhead ratios — the paper
    /// tile has only 18 outputs, so integer rounding distorts percentages.
    pub fn latency_cycles_exact(&self) -> f64 {
        let outputs = self.outputs() as f64;
        let depth = 10.0; // pipeline fill (mul stages + tree stages)
        let base = if self.hls.unroll_taps {
            outputs + depth
        } else {
            outputs * self.taps() as f64 + depth
        };
        match self.variant {
            ConvVariantKind::Direct | ConvVariantKind::WeightShared => base,
            ConvVariantKind::Pasm if !self.hls.partition_bins => {
                // §5.3 fallback (imageBin unpartitioned): the PAS RMW
                // serializes to one tap per cycle and the post-pass drains
                // B bins per output — the paper's §4 streaming formula
                // `N + B` per output.
                outputs
                    * (self.taps() as f64
                        + self.bins as f64 / self.hls.postpass_muls as f64)
                    + depth
            }
            ConvVariantKind::Pasm => {
                let extra = PASM_LATENCY_FIXED
                    + outputs * self.bins as f64
                        / (PASM_POSTPASS_OVERLAP * self.hls.postpass_muls as f64);
                base + extra
            }
        }
    }

    /// Latency in seconds at the tech clock.
    pub fn latency_s(&self, tech: &Tech) -> f64 {
        self.latency_cycles() as f64 * tech.period_s()
    }
}

/// Wrap a raw gate breakdown back into a Component (for aggregation).
fn component_from(gates: GateBreakdown, name: &str, activity: f64, depth: f64) -> Component {
    Component { name: name.into(), gates, activity, depth_levels: depth, max_fanout: 1.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_pair(bins: usize, ww: u32) -> (ConvAccel, ConvAccel) {
        (
            ConvAccel::paper(ConvVariantKind::WeightShared, bins, ww),
            ConvAccel::paper(ConvVariantKind::Pasm, bins, ww),
        )
    }

    #[test]
    fn pasm_wins_4bin_32bit_asic() {
        // Fig 15: ~48% fewer gates, ~53% less power at 4-bin/32-bit, 1 GHz
        let t = Tech::asic_1ghz();
        let (ws, pasm) = paper_pair(4, 32);
        let g = 1.0 - pasm.gates(&t).total() / ws.gates(&t).total();
        let p = 1.0 - pasm.power(&t).total_w() / ws.power(&t).total_w();
        assert!(g > 0.3, "gate saving {g}");
        assert!(p > 0.3, "power saving {p}");
    }

    #[test]
    fn pasm_loses_16bin_32bit_asic_1ghz() {
        // Fig 17: at 16-bin/32-bit the 1 GHz ASIC flips against PASM
        let t = Tech::asic_1ghz();
        let (ws, pasm) = paper_pair(16, 32);
        assert!(
            pasm.gates(&t).total() > ws.gates(&t).total(),
            "pasm {} vs ws {}",
            pasm.gates(&t).total(),
            ws.gates(&t).total()
        );
    }

    #[test]
    fn savings_shrink_with_bins() {
        let t = Tech::asic_1ghz();
        let saving = |b: usize| {
            let (ws, pasm) = paper_pair(b, 32);
            1.0 - pasm.gates(&t).total() / ws.gates(&t).total()
        };
        assert!(saving(4) > saving(8));
        assert!(saving(8) > saving(16));
    }

    #[test]
    fn relaxed_clock_rescues_16bin() {
        // §5.1: "it might be better to target a lower clock frequency"
        let relaxed = Tech::asic_800mhz();
        let (ws, pasm) = paper_pair(16, 32);
        let saving_800 = 1.0 - pasm.gates(&relaxed).total() / ws.gates(&relaxed).total();
        let t1g = Tech::asic_1ghz();
        let saving_1g = 1.0 - pasm.gates(&t1g).total() / ws.gates(&t1g).total();
        assert!(saving_800 > saving_1g);
    }

    #[test]
    fn latency_overhead_in_paper_band() {
        // Fig 14: PASM latency +8.5% (4-bin) .. +12.75% (16-bin)
        for (bins, lo, hi) in [(4usize, 0.06, 0.11), (8, 0.07, 0.12), (16, 0.10, 0.15)] {
            let (ws, pasm) = paper_pair(bins, 32);
            let overhead =
                pasm.latency_cycles_exact() / ws.latency_cycles_exact() - 1.0;
            assert!(
                overhead > lo && overhead < hi,
                "bins {bins}: overhead {overhead}"
            );
        }
    }

    #[test]
    fn more_postpass_muls_cut_latency() {
        let mut pasm = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32);
        let l1 = pasm.latency_cycles();
        pasm.hls = pasm.hls.with_postpass_muls(4);
        let l4 = pasm.latency_cycles();
        assert!(l4 < l1);
    }

    #[test]
    fn direct_vs_ws_close() {
        // weight sharing alone barely changes the MAC array (paper Fig 15:
        // non-WS and WS are within a few percent of each other)
        let t = Tech::asic_1ghz();
        let d = ConvAccel::paper(ConvVariantKind::Direct, 4, 32).gates(&t).total();
        let w = ConvAccel::paper(ConvVariantKind::WeightShared, 4, 32).gates(&t).total();
        let ratio = d / w;
        assert!(ratio > 0.8 && ratio < 1.25, "ratio {ratio}");
    }

    #[test]
    fn eight_bit_kernels_still_win_at_4bin() {
        // Fig 18: 8-bit kernels, 4 bins -> PASM still ahead
        let t = Tech::asic_1ghz();
        let (ws, pasm) = paper_pair(4, 8);
        assert!(pasm.gates(&t).total() < ws.gates(&t).total());
        assert!(pasm.power(&t).total_w() < ws.power(&t).total_w());
    }

    #[test]
    fn unpartitioned_bins_tiny_but_slow() {
        // §5.3: "implement the imageBin in dual port BRAM and incur a
        // slight increase in latency" — at the paper tile the serialized
        // PAS costs ~taps x more cycles but collapses the gather fabric
        let t = Tech::asic_1ghz();
        let partitioned = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32);
        let mut banked = partitioned.clone();
        banked.hls.partition_bins = false;
        assert!(banked.gates(&t).total() < partitioned.gates(&t).total() / 5.0);
        assert!(banked.latency_cycles() > 10 * partitioned.latency_cycles());
        // the unpartitioned design never hits timing pressure
        assert!(banked.path_utilization(&t) < 1.0);
    }

    #[test]
    fn unpartitioned_follows_paper_streaming_formula() {
        // N + B per output (paper §4)
        let mut a = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32);
        a.hls.partition_bins = false;
        let outputs = 2.0 * 9.0;
        let want = outputs * (135.0 + 16.0) + 10.0;
        assert!((a.latency_cycles_exact() - want).abs() < 1e-9);
    }

    #[test]
    fn sequential_hls_much_slower_but_smaller() {
        let t = Tech::asic_1ghz();
        let unrolled = ConvAccel::paper(ConvVariantKind::WeightShared, 4, 32);
        let mut seq = unrolled.clone();
        seq.hls = HlsConfig::sequential();
        assert!(seq.latency_cycles() > 10 * unrolled.latency_cycles());
        assert!(seq.gates(&t).total() < unrolled.gates(&t).total() / 4.0);
    }
}
