//! Accelerator structural models: the designs the paper synthesizes.
//!
//! * [`standalone`] — §2.4's unit-level experiment: a weight-shared
//!   **16-MAC** vs the proposed **16-PAS-4-MAC**, streaming one input pair
//!   per unit per cycle (Verilog, 100 MHz).  Reproduces Figs 7-10.
//! * [`conv`] — §3-4's CNN convolution-layer accelerators: non-weight-
//!   shared, weight-shared, and weight-shared-with-PASM variants of the
//!   AlexNet tile (C=15, 5x5 image, 3x3 kernels, M=2), HLS-style fully
//!   unrolled across taps with II=1 pipelining (Vivado_HLS → Genus, 1 GHz).
//!   Reproduces Figs 14-18 (and, via [`crate::fpga`], Figs 19-22).
//! * [`hls`] — the directive knobs of Fig 13 (UNROLL / PIPELINE /
//!   ARRAY_PARTITION / ALLOCATION) as configuration.
//! * [`pipeline`] — retiming helper: cuts a combinational component into
//!   enough stages to meet the clock, paying pipeline registers, exactly
//!   the trade the paper describes (§4: latency cut 92 % for 97 % more
//!   flip-flops).

pub mod conv;
pub mod hls;
pub mod pipeline;
pub mod standalone;

pub use conv::{ConvAccel, ConvVariantKind};
pub use hls::HlsConfig;
pub use standalone::{StandaloneReport, StandaloneUnit, UnitKind};
