//! Observability primitives: mergeable log-bucketed latency histograms
//! and lock-free request-lifecycle trace rings.
//!
//! Two building blocks, both fixed-size and allocation-free on the hot
//! path, shared by the coordinator's metrics layer and both serving
//! front-ends (see the "Observability" section of
//! `docs/ARCHITECTURE.md`):
//!
//! * [`LogHistogram`] — an HDR-style histogram over `u64` microsecond
//!   values: [`SUB`] sub-buckets per power of two, so every bucket's
//!   relative width is at most `1/SUB` (~3.1%) and
//!   [`LogHistogram::percentile_us`] is exact *within a bucket*.
//!   Histograms merge by bucket-wise addition — associative,
//!   commutative, and bounded ([`BUCKET_COUNT`] counters, ever), which
//!   is what lets per-shard snapshots combine into one coordinator
//!   snapshot without the unbounded-concatenation bug the old
//!   sliding-window percentiles had.  [`StageHistograms`] bundles one
//!   histogram per request stage (queue-wait, batch-form, execute,
//!   write-back).
//!
//! * [`TraceBuf`] — per-shard rings of [`TraceEvent`]s recorded with a
//!   seqlock discipline over plain atomics: a writer claims a ticket
//!   with one `fetch_add`, marks the slot odd, stores the event fields,
//!   and marks it even again; readers ([`TraceBuf::snapshot`]) copy a
//!   slot and accept it only if the sequence word was even and unchanged
//!   around the copy.  Recording is wait-free, never allocates, and
//!   costs a handful of relaxed atomic stores — cheap enough to leave on
//!   in production (the coordinator bench gates the overhead at ≤ 2%
//!   throughput).  The ring overwrites oldest-first; a trace is a
//!   recent-history debugging view, not an audit log.
//!
//! Events carry a [`Stage`] and an `aux` word whose meaning is
//! per-stage (queue depth at `enqueued`, chosen bucket at
//! `batch_formed` / `launched`, `compute_us` at `executed`, reply bytes
//! at `reply_written`, queued µs at `deadline_drop`, the injected fault
//! kind at `fault`, the error-code ordinal at `retried`).  Spans are
//! assembled client-side by request id ([`assemble_spans`]); a span is
//! *complete* when every lifecycle stage from `accepted` through
//! `reply_written` is present with non-decreasing timestamps.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering, fence};
use std::time::{Duration, Instant};

/// log2 of [`SUB`]: the histogram keeps `2^SUB_BITS` sub-buckets per
/// power of two.
pub const SUB_BITS: u32 = 5;

/// Sub-buckets per octave; the worst-case relative bucket width is
/// `1/SUB`.
pub const SUB: u64 = 1 << SUB_BITS;

/// Octave groups above the linear region: exponents `SUB_BITS..=31`,
/// so every value below `2^32` µs (~71 minutes) lands in a bucket with
/// bounded relative error and anything larger saturates into the last
/// bucket (the exact maximum is tracked separately).
const GROUPS: usize = 27;

/// Total buckets in a [`LogHistogram`] — the histogram's entire, fixed
/// memory footprint is `BUCKET_COUNT` u64 counters.
pub const BUCKET_COUNT: usize = (SUB as usize) * (GROUPS + 1);

/// Bucket index of value `v`: identity below [`SUB`], then `SUB`
/// sub-buckets per octave; values at or above `2^32` saturate into the
/// last bucket.
fn bucket_index(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let exp = 63 - u64::from(v.leading_zeros());
    let group = (exp - u64::from(SUB_BITS)) as usize;
    if group >= GROUPS {
        return BUCKET_COUNT - 1;
    }
    let sub = (v >> (exp - u64::from(SUB_BITS))) - SUB;
    (SUB as usize) * (group + 1) + sub as usize
}

/// Largest value mapping into bucket `idx` (inclusive upper edge).
fn bucket_high(idx: usize) -> u64 {
    if idx < SUB as usize {
        return idx as u64;
    }
    let group = idx / SUB as usize - 1;
    let sub = (idx % SUB as usize) as u64;
    ((SUB + sub + 1) << group) - 1
}

/// A fixed-size log-bucketed latency histogram (microsecond values).
///
/// Memory is bounded by construction ([`BUCKET_COUNT`] counters, lazily
/// allocated on first record so empty histograms stay a few words), and
/// [`LogHistogram::merge`] is bucket-wise addition — associative and
/// commutative, so any merge order of per-shard snapshots yields the
/// same totals.  [`LogHistogram::percentile_us`] reports the inclusive
/// upper edge of the bucket holding the ranked sample, clamped to the
/// exact observed maximum: conservative, monotone in `p`, and within
/// `1/SUB` relative error of the true order statistic.
#[derive(Clone, Default, PartialEq, Eq)]
pub struct LogHistogram {
    /// Bucket counters; empty until the first record, then exactly
    /// [`BUCKET_COUNT`] long.
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
}

impl fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LogHistogram")
            .field("count", &self.count)
            .field("sum", &self.sum)
            .field("max", &self.max)
            .field("p50", &self.percentile_us(50.0))
            .field("p99", &self.percentile_us(99.0))
            .finish()
    }
}

impl LogHistogram {
    /// An empty histogram (no buckets allocated yet).
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    /// Whether any value was recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Values recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded values (µs), saturating.
    pub fn sum_us(&self) -> u64 {
        self.sum
    }

    /// Exact maximum recorded value (µs); 0 when empty.
    pub fn max_us(&self) -> u64 {
        self.max
    }

    /// Mean of recorded values (µs); `None` when empty.
    pub fn mean_us(&self) -> Option<f64> {
        if self.count == 0 { None } else { Some(self.sum as f64 / self.count as f64) }
    }

    /// Record one value (µs).
    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKET_COUNT];
        }
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    /// Record a duration, truncated to whole microseconds.
    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Merge `other` into `self` by bucket-wise addition.  Unlike the
    /// sliding-window concatenation this replaced, the result is
    /// independent of merge order and never grows beyond
    /// [`BUCKET_COUNT`] counters, and an idle shard contributes exactly
    /// its own samples' weight.
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.count == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKET_COUNT];
        }
        for (mine, theirs) in self.counts.iter_mut().zip(&other.counts) {
            *mine += *theirs;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Latency percentile (`p` in `[0, 100]`), `None` when empty.
    ///
    /// Uses the same rank convention as the exact sort-based percentile
    /// it replaced (`rank = round(p/100 · (n−1))`), returning the upper
    /// edge of the bucket holding that rank clamped to the exact
    /// maximum — so `p = 100` is exact and every answer is within
    /// `1/SUB` relative error above the true order statistic.
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Some(bucket_high(i).min(self.max));
            }
        }
        Some(self.max)
    }

    /// Non-empty buckets as `(index, count)` pairs, ascending by index —
    /// the wire representation (`docs/WIRE_PROTOCOL.md`, `metrics`
    /// frame).
    pub fn to_sparse(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Rebuild a histogram from its wire representation.  Indices out
    /// of range are clamped into the last bucket; the total count is
    /// recomputed from the buckets.
    pub fn from_sparse(sum_us: u64, max_us: u64, buckets: &[(usize, u64)]) -> LogHistogram {
        let mut h = LogHistogram::new();
        if buckets.is_empty() {
            return h;
        }
        h.counts = vec![0; BUCKET_COUNT];
        for &(idx, c) in buckets {
            h.counts[idx.min(BUCKET_COUNT - 1)] += c;
            h.count += c;
        }
        h.sum = sum_us;
        h.max = max_us;
        h
    }
}

/// One [`LogHistogram`] per request stage: where a request's latency
/// goes between arriving and being answered.
#[derive(Clone, Default, Debug, PartialEq, Eq)]
pub struct StageHistograms {
    /// Enqueue → batch formation (per request).
    pub queue: LogHistogram,
    /// Batch formation overhead: drain + padding + executable resolve,
    /// excluding kernel execution (per batch).
    pub batch_form: LogHistogram,
    /// Kernel execution (per batch, the engine's `compute_us`).
    pub execute: LogHistogram,
    /// Reply encode + socket write on the front-end (per reply).
    pub write_back: LogHistogram,
}

impl StageHistograms {
    /// Merge another set of stage histograms into this one, bucket-wise.
    pub fn merge(&mut self, other: &StageHistograms) {
        self.queue.merge(&other.queue);
        self.batch_form.merge(&other.batch_form);
        self.execute.merge(&other.execute);
        self.write_back.merge(&other.write_back);
    }

    /// Whether every stage histogram is empty.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
            && self.batch_form.is_empty()
            && self.execute.is_empty()
            && self.write_back.is_empty()
    }

    /// The four stages as `(name, histogram)` pairs, in pipeline order.
    pub fn named(&self) -> [(&'static str, &LogHistogram); 4] {
        [
            ("queue", &self.queue),
            ("batch_form", &self.batch_form),
            ("execute", &self.execute),
            ("write_back", &self.write_back),
        ]
    }
}

/// A point in a request's lifecycle (or a terminal/fault annotation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum Stage {
    /// Frame header fully read off the socket.
    Accepted = 0,
    /// Wire frame decoded and validated.
    Decoded = 1,
    /// Request placed on its shard's per-model queue.
    Enqueued = 2,
    /// The batcher chose a bucket and the request was drained into a
    /// batch.
    BatchFormed = 3,
    /// Executable resolved; kernel execution about to start.
    Launched = 4,
    /// Kernel execution finished.
    Executed = 5,
    /// Reply handed to the socket (threaded: write completed; evented:
    /// queued on the connection's write buffer).
    ReplyWritten = 6,
    /// The request's deadline expired before a batch launched; it was
    /// dropped from the queue with a typed error.
    DeadlineDrop = 7,
    /// A fault-injection event fired on this request's path (`aux` is
    /// the [`fault kind`](crate::faults) code).
    Fault = 8,
    /// The request was answered with a retryable error code; a client
    /// retry arrives as a fresh request id, i.e. a new span.
    Retried = 9,
    /// The request's formed batch was stolen by an idle shard: it
    /// executed on a shard other than its model's home (`aux` is the
    /// home shard id; the event's `shard` is the executing shard).
    Stolen = 10,
}

impl Stage {
    /// The happy-path lifecycle, in order — a *complete* span contains
    /// all of these with non-decreasing timestamps.
    pub const LIFECYCLE: [Stage; 7] = [
        Stage::Accepted,
        Stage::Decoded,
        Stage::Enqueued,
        Stage::BatchFormed,
        Stage::Launched,
        Stage::Executed,
        Stage::ReplyWritten,
    ];

    /// Wire name of the stage.
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Accepted => "accepted",
            Stage::Decoded => "decoded",
            Stage::Enqueued => "enqueued",
            Stage::BatchFormed => "batch_formed",
            Stage::Launched => "launched",
            Stage::Executed => "executed",
            Stage::ReplyWritten => "reply_written",
            Stage::DeadlineDrop => "deadline_drop",
            Stage::Fault => "fault",
            Stage::Retried => "retried",
            Stage::Stolen => "stolen",
        }
    }

    /// Parse a wire stage name.
    pub fn parse(s: &str) -> Option<Stage> {
        [
            Stage::Accepted,
            Stage::Decoded,
            Stage::Enqueued,
            Stage::BatchFormed,
            Stage::Launched,
            Stage::Executed,
            Stage::ReplyWritten,
            Stage::DeadlineDrop,
            Stage::Fault,
            Stage::Retried,
            Stage::Stolen,
        ]
        .into_iter()
        .find(|st| st.as_str() == s)
    }

    fn from_u8(b: u8) -> Option<Stage> {
        Stage::parse(match b {
            0 => "accepted",
            1 => "decoded",
            2 => "enqueued",
            3 => "batch_formed",
            4 => "launched",
            5 => "executed",
            6 => "reply_written",
            7 => "deadline_drop",
            8 => "fault",
            9 => "retried",
            10 => "stolen",
            _ => return None,
        })
    }
}

/// One recorded lifecycle event, copied out of a trace ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Coordinator-assigned request id (0 = shard-level event, e.g. a
    /// worker-kill fault).
    pub id: u64,
    /// Shard that recorded the event.
    pub shard: usize,
    /// What happened.
    pub stage: Stage,
    /// Microseconds since the trace buffer's origin instant.
    pub t_us: u64,
    /// Per-stage auxiliary word (see the module docs).
    pub aux: u64,
}

/// Default per-shard trace-ring capacity (events), used by the
/// coordinator builder when tracing is enabled without an explicit
/// capacity.
pub const DEFAULT_TRACE_CAPACITY: usize = 1024;

/// One seqlock-guarded event slot.  `seq == 0` means never written;
/// odd means a write is in progress; even means the other four words
/// are a consistent event.
struct Slot {
    seq: AtomicU64,
    id: AtomicU64,
    /// `stage | shard << 8`.
    meta: AtomicU64,
    t_us: AtomicU64,
    aux: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            id: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            t_us: AtomicU64::new(0),
            aux: AtomicU64::new(0),
        }
    }
}

/// One shard's ring: a ticket counter plus a fixed slot array.
struct Ring {
    head: AtomicU64,
    slots: Box<[Slot]>,
}

/// Fixed-capacity, lock-free request-lifecycle trace rings, one per
/// shard.
///
/// Writers never block and never allocate: recording is one
/// `fetch_add` (the ticket) plus five atomic stores under a seqlock
/// discipline.  [`TraceBuf::snapshot`] copies every consistent slot;
/// a slot being concurrently overwritten is simply skipped.  The ring
/// overwrites oldest events once full — capacity bounds memory, not
/// history.
///
/// All timestamps are microseconds since the buffer's origin instant
/// (captured at construction), so events from different shards and the
/// front-end share one clock.
pub struct TraceBuf {
    rings: Vec<Ring>,
    origin: Instant,
}

impl TraceBuf {
    /// A trace buffer with `shards` rings of `capacity` slots each
    /// (capacity is clamped to at least 16).
    pub fn new(shards: usize, capacity: usize) -> TraceBuf {
        let capacity = capacity.max(16);
        let rings = (0..shards.max(1))
            .map(|_| Ring {
                head: AtomicU64::new(0),
                slots: (0..capacity).map(|_| Slot::new()).collect(),
            })
            .collect();
        TraceBuf { rings, origin: Instant::now() }
    }

    /// Number of per-shard rings.
    pub fn shards(&self) -> usize {
        self.rings.len()
    }

    /// Per-shard ring capacity (events).
    pub fn capacity(&self) -> usize {
        self.rings[0].slots.len()
    }

    /// Microseconds since the buffer's origin.
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// Record an event stamped `now`.
    pub fn record(&self, shard: usize, id: u64, stage: Stage, aux: u64) {
        self.record_at(shard, id, stage, Instant::now(), aux);
    }

    /// Record an event stamped at `at` (e.g. an ingress instant captured
    /// by the front-end before the request reached the shard).
    pub fn record_at(&self, shard: usize, id: u64, stage: Stage, at: Instant, aux: u64) {
        let ring = &self.rings[shard % self.rings.len()];
        let t_us = at.saturating_duration_since(self.origin).as_micros() as u64;
        let cap = ring.slots.len() as u64;
        let ticket = ring.head.fetch_add(1, Ordering::Relaxed);
        let slot = &ring.slots[(ticket % cap) as usize];
        // seqlock write: odd marks in-progress; the final even value is
        // derived from the ticket so lapped writers publish distinct
        // sequence numbers and readers reject interleavings
        let ver = (ticket / cap) * 2;
        slot.seq.store(ver + 1, Ordering::Release);
        slot.id.store(id, Ordering::Relaxed);
        slot.meta.store(stage as u64 | ((shard as u64) << 8), Ordering::Relaxed);
        slot.t_us.store(t_us, Ordering::Relaxed);
        slot.aux.store(aux, Ordering::Relaxed);
        slot.seq.store(ver + 2, Ordering::Release);
    }

    /// Copy every consistent event out of every ring, sorted by
    /// timestamp (ties broken by id, then stage order).  Slots being
    /// concurrently overwritten are skipped, not torn.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for ring in &self.rings {
            for slot in ring.slots.iter() {
                for _ in 0..4 {
                    let s1 = slot.seq.load(Ordering::Acquire);
                    if s1 == 0 || s1 & 1 == 1 {
                        break;
                    }
                    let id = slot.id.load(Ordering::Relaxed);
                    let meta = slot.meta.load(Ordering::Relaxed);
                    let t_us = slot.t_us.load(Ordering::Relaxed);
                    let aux = slot.aux.load(Ordering::Relaxed);
                    fence(Ordering::Acquire);
                    if slot.seq.load(Ordering::Relaxed) != s1 {
                        continue; // overwritten mid-copy; retry
                    }
                    if let Some(stage) = Stage::from_u8((meta & 0xff) as u8) {
                        out.push(TraceEvent {
                            id,
                            shard: (meta >> 8) as usize,
                            stage,
                            t_us,
                            aux,
                        });
                    }
                    break;
                }
            }
        }
        out.sort_by_key(|e| (e.t_us, e.id, e.stage));
        out
    }
}

/// All events of one request id, time-sorted.
#[derive(Clone, Debug)]
pub struct Span {
    /// The coordinator request id the events share.
    pub id: u64,
    /// The events, sorted by `(t_us, stage)`.
    pub events: Vec<TraceEvent>,
}

impl Span {
    /// Earliest timestamp recorded for `stage`, if present.
    pub fn stage_time(&self, stage: Stage) -> Option<u64> {
        self.events.iter().filter(|e| e.stage == stage).map(|e| e.t_us).min()
    }

    /// Whether every lifecycle stage (`accepted` → `reply_written`) is
    /// present with non-decreasing timestamps.
    pub fn is_complete(&self) -> bool {
        let mut last = 0u64;
        for stage in Stage::LIFECYCLE {
            match self.stage_time(stage) {
                Some(t) if t >= last => last = t,
                _ => return false,
            }
        }
        true
    }
}

/// Group events into per-request spans (id 0 — shard-level events — is
/// excluded), sorted by each span's first timestamp.
pub fn assemble_spans(events: &[TraceEvent]) -> Vec<Span> {
    let mut by_id: std::collections::BTreeMap<u64, Vec<TraceEvent>> = Default::default();
    for e in events {
        if e.id != 0 {
            by_id.entry(e.id).or_default().push(*e);
        }
    }
    let mut spans: Vec<Span> = by_id
        .into_iter()
        .map(|(id, mut events)| {
            events.sort_by_key(|e| (e.t_us, e.stage));
            Span { id, events }
        })
        .collect();
    spans.sort_by_key(|s| s.events.first().map(|e| e.t_us).unwrap_or(0));
    spans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::Rng;
    use std::sync::Arc;

    fn random_histogram(seed: u64, n: usize) -> LogHistogram {
        let mut rng = Rng::new(seed);
        let mut h = LogHistogram::new();
        for _ in 0..n {
            h.record(rng.next_u64() >> (rng.next_u64() % 48));
        }
        h
    }

    #[test]
    fn bucket_index_is_monotone_and_continuous() {
        let mut last = 0usize;
        for v in 0..100_000u64 {
            let idx = bucket_index(v);
            assert!(idx >= last, "index not monotone at {v}");
            assert!(idx <= last + 1, "index skipped a bucket at {v}");
            last = idx;
            assert!(bucket_high(idx) >= v, "upper edge below value at {v}");
        }
    }

    #[test]
    fn bucket_relative_error_is_bounded() {
        let mut rng = Rng::new(3);
        for _ in 0..100_000 {
            let v = rng.next_u64() % (1u64 << 32);
            let high = bucket_high(bucket_index(v));
            assert!(high >= v);
            assert!(
                high - v <= v / SUB + 1,
                "bucket error {} exceeds bound for {v}",
                high - v
            );
        }
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in 0..64u64 {
            h.record(v);
        }
        for v in 0..64u64 {
            let p = 100.0 * v as f64 / 63.0;
            assert_eq!(h.percentile_us(p), Some(v));
        }
    }

    #[test]
    fn percentile_matches_exact_within_bucket_error() {
        let mut rng = Rng::new(17);
        let mut h = LogHistogram::new();
        let mut exact: Vec<u64> = Vec::new();
        for _ in 0..10_000 {
            let v = rng.next_u64() % 5_000_000;
            h.record(v);
            exact.push(v);
        }
        exact.sort_unstable();
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            let rank = ((p / 100.0) * (exact.len() - 1) as f64).round() as usize;
            let truth = exact[rank];
            let got = h.percentile_us(p).unwrap();
            assert!(got >= truth, "p{p}: {got} < exact {truth}");
            assert!(got <= truth + truth / SUB + 1, "p{p}: {got} too far above exact {truth}");
        }
        assert_eq!(h.percentile_us(100.0), Some(*exact.last().unwrap()));
    }

    #[test]
    fn percentile_is_monotone_in_p() {
        let h = random_histogram(29, 5_000);
        let mut last = 0u64;
        for tenth in 0..=1000 {
            let got = h.percentile_us(tenth as f64 / 10.0).unwrap();
            assert!(got >= last, "p{} regressed", tenth as f64 / 10.0);
            last = got;
        }
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        let a = random_histogram(1, 3000);
        let b = random_histogram(2, 500);
        let c = random_histogram(3, 7000);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);

        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
        assert_eq!(ab_c.count(), a.count() + b.count() + c.count());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let a = random_histogram(5, 100);
        let mut merged = a.clone();
        merged.merge(&LogHistogram::new());
        assert_eq!(merged, a);
        let mut empty = LogHistogram::new();
        empty.merge(&a);
        assert_eq!(empty, a);
    }

    #[test]
    fn memory_is_bounded_regardless_of_volume() {
        let mut h = LogHistogram::new();
        let mut rng = Rng::new(11);
        for _ in 0..1_000_000 {
            h.record(rng.next_u64());
        }
        assert_eq!(h.counts.len(), BUCKET_COUNT);
        assert_eq!(h.count(), 1_000_000);
        // and merging a shard's worth more does not grow it either
        let other = random_histogram(12, 100_000);
        h.merge(&other);
        assert_eq!(h.counts.len(), BUCKET_COUNT);
    }

    #[test]
    fn sparse_round_trips() {
        let h = random_histogram(23, 4_000);
        let sparse = h.to_sparse();
        assert!(sparse.windows(2).all(|w| w[0].0 < w[1].0), "sparse not ascending");
        let back = LogHistogram::from_sparse(h.sum_us(), h.max_us(), &sparse);
        assert_eq!(back, h);
        assert_eq!(LogHistogram::from_sparse(0, 0, &[]), LogHistogram::new());
    }

    #[test]
    fn saturated_values_report_exact_max() {
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 3);
        assert_eq!(h.percentile_us(100.0), Some(u64::MAX));
        assert_eq!(h.max_us(), u64::MAX);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn stage_names_round_trip() {
        for stage in [
            Stage::Accepted,
            Stage::Decoded,
            Stage::Enqueued,
            Stage::BatchFormed,
            Stage::Launched,
            Stage::Executed,
            Stage::ReplyWritten,
            Stage::DeadlineDrop,
            Stage::Fault,
            Stage::Retried,
            Stage::Stolen,
        ] {
            assert_eq!(Stage::parse(stage.as_str()), Some(stage));
            assert_eq!(Stage::from_u8(stage as u8), Some(stage));
        }
        assert_eq!(Stage::parse("no_such_stage"), None);
        assert_eq!(Stage::from_u8(200), None);
    }

    #[test]
    fn trace_ring_records_and_snapshots() {
        let buf = TraceBuf::new(2, 64);
        let t = Instant::now();
        for (i, stage) in Stage::LIFECYCLE.into_iter().enumerate() {
            buf.record_at(1, 42, stage, t + Duration::from_micros(i as u64 * 10), i as u64);
        }
        let events = buf.snapshot();
        assert_eq!(events.len(), 7);
        assert!(events.iter().all(|e| e.id == 42 && e.shard == 1));
        let spans = assemble_spans(&events);
        assert_eq!(spans.len(), 1);
        assert!(spans[0].is_complete());
        assert!(spans[0].stage_time(Stage::Accepted) <= spans[0].stage_time(Stage::ReplyWritten));
    }

    #[test]
    fn incomplete_spans_are_detected() {
        let buf = TraceBuf::new(1, 64);
        buf.record(0, 7, Stage::Enqueued, 0);
        buf.record(0, 7, Stage::DeadlineDrop, 1500);
        let spans = assemble_spans(&buf.snapshot());
        assert_eq!(spans.len(), 1);
        assert!(!spans[0].is_complete());
        assert!(spans[0].stage_time(Stage::DeadlineDrop).is_some());
    }

    #[test]
    fn ring_wraps_and_stays_bounded() {
        let buf = TraceBuf::new(1, 64);
        for i in 0..10_000u64 {
            buf.record(0, i + 1, Stage::Enqueued, i);
        }
        let events = buf.snapshot();
        assert!(events.len() <= 64);
        // only recent ids survive the wrap
        assert!(events.iter().all(|e| e.id > 10_000 - 128));
    }

    #[test]
    fn concurrent_writers_never_tear_events() {
        // every writer stamps aux = id ^ MAGIC; a torn slot (fields from
        // two different writes) would break that invariant
        const MAGIC: u64 = 0x5ca1_ab1e_0ddb_4111;
        let buf = Arc::new(TraceBuf::new(2, 128));
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let buf = Arc::clone(&buf);
            handles.push(std::thread::spawn(move || {
                for i in 0..20_000u64 {
                    let id = w * 1_000_000 + i + 1;
                    buf.record((w % 2) as usize, id, Stage::Enqueued, id ^ MAGIC);
                }
            }));
        }
        let reader = {
            let buf = Arc::clone(&buf);
            std::thread::spawn(move || {
                let mut checked = 0usize;
                for _ in 0..200 {
                    for e in buf.snapshot() {
                        assert_eq!(e.aux, e.id ^ MAGIC, "torn trace event");
                        checked += 1;
                    }
                }
                checked
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        assert!(reader.join().unwrap() > 0);
    }
}
