//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The build-time python side (`python/compile/aot.py`) lowers the L2
//! graphs to **HLO text** under `artifacts/` (text, not serialized proto —
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids).  This module is the request-
//! path half: it parses `artifacts/manifest.json`, compiles each HLO
//! module on the PJRT CPU client once at startup, and exposes typed
//! execute calls.  Python never runs at inference time.
//!
//! The PJRT client (`client`) depends on the `xla` crate from the AOT
//! toolchain image and is gated behind the `pjrt` cargo feature; the
//! manifest and JSON layers are dependency-free and always available (the
//! default build serves through the coordinator's `NativeBackend` instead).
//!
//! * [`json`] — minimal JSON parser (the offline build has no serde_json).
//! * [`manifest`] — typed view of `artifacts/manifest.json`.
//! * `client` — PJRT client wrapper + literal marshalling (feature `pjrt`).

#[cfg(feature = "pjrt")]
pub mod client;
pub mod json;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use client::{ModelExecutable, Runtime, TileExecutable};
pub use manifest::{ArtifactManifest, ModelSpec, TileSpec};
