//! Minimal JSON layer: hand-rolled parser + canonical serializer.
//!
//! The offline build environment has no serde_json, so we parse and write
//! by hand.  [`parse`] supports the full JSON value grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null); numbers are
//! kept as f64 (manifest values are small integers, exactly
//! representable).  The [`fmt::Display`] impl is the inverse direction,
//! used by the network wire protocol ([`crate::serving::proto`]): it
//! emits **canonical** JSON — compact (no whitespace), object keys in
//! lexicographic order (a [`BTreeMap`] invariant), and floats in Rust's
//! shortest round-trip decimal form — so a given `Json` value always
//! serializes to exactly one byte sequence.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (kept as f64; integers ≤ 2^53 are exact).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; `BTreeMap` keeps keys sorted, making serialization
    /// canonical.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field lookup; `None` for non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to `usize`, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    /// Canonical serialization: compact, sorted keys, shortest
    /// round-tripping float form (Rust's `{}` for f64 — never scientific
    /// notation, so the output re-parses to the identical value).
    /// Non-finite numbers have no JSON form and serialize as `null`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(n) if n.is_finite() => write!(f, "{n}"),
            Json::Num(_) => f.write_str("null"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{item}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (key, val)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, key)?;
                    write!(f, ":{val}")?;
                }
                f.write_str("}")
            }
        }
    }
}

/// Write `s` as a JSON string literal (quotes, `\"`, `\\`, and control
/// characters escaped; multibyte UTF-8 passes through verbatim).
fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            '\u{0008}' => f.write_str("\\b")?,
            '\u{000C}' => f.write_str("\\f")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset in the input where parsing failed.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences verbatim
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn escapes_and_unicode() {
        let v = parse(r#""line\nquote\" uA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "line\nquote\" uA");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse("  {\n\t\"k\" :  [ 1 , 2 ]\r\n}  ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn serializes_canonically() {
        let v = parse(r#"{ "b" : [1, 2.5, true, null], "a": {"k": "v"} }"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":{"k":"v"},"b":[1,2.5,true,null]}"#);
    }

    #[test]
    fn serialize_parse_round_trip() {
        let cases = [
            r#"{"data":[0,0.5,1],"dims":[1,12,12],"id":7,"type":"infer","v":1}"#,
            r#"[-12.5,0.0000011,100000000000000000000]"#,
            r#"{"empty_arr":[],"empty_obj":{},"nested":[[1],[2,[3]]]}"#,
            r#""line\nquote\" tab\t""#,
            "\"héllo→\"",
        ];
        for case in cases {
            let v = parse(case).unwrap();
            assert_eq!(v.to_string(), case, "canonical form must round-trip");
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn serialize_escapes_control_chars() {
        let v = Json::Str("a\u{0001}b\u{0008}c".into());
        assert_eq!(v.to_string(), r#""a\u0001b\bc""#);
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn f32_survives_f64_round_trip() {
        // the wire protocol carries f32 tensors as JSON numbers: f32 → f64
        // is exact, Display round-trips f64, and casting back to f32
        // recovers the original bits for every finite value
        for bits in [0u32, 0x3f000000, 0x3f800001, 0x7f7fffff, 0x00000001, 0xbf99999a] {
            let x = f32::from_bits(bits);
            let s = Json::Num(x as f64).to_string();
            let back = parse(&s).unwrap().as_f64().unwrap() as f32;
            assert_eq!(back.to_bits(), bits, "{x} -> {s} -> {back}");
        }
    }

    #[test]
    fn real_manifest_shape() {
        let doc = r#"{
            "format": "hlo-text",
            "tile": {"channels": 15, "bins": 16},
            "model_param_order": ["bi1", "cb1"],
            "artifacts": {"pasm_tile": "pasm_tile.hlo.txt"}
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        assert_eq!(v.get("tile").unwrap().get("bins").unwrap().as_usize(), Some(16));
    }
}
