//! PJRT client wrapper: compile HLO-text artifacts once, execute many.
//!
//! Follows the /opt/xla-example/load_hlo pattern: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Lowering used `return_tuple=True`, so
//! every output is a 1-tuple unwrapped with `to_tuple1`.

use crate::runtime::manifest::ArtifactManifest;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// The PJRT runtime: one CPU client plus the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    /// The loaded artifact manifest.
    pub manifest: ArtifactManifest,
}

/// A compiled conv-tile executable (pasm_tile / ws_tile).
pub struct TileExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name ("pasm_tile" / "ws_tile").
    pub name: String,
    /// Input image dims `(C, IH, IW)`.
    pub image_dims: [usize; 3],
    /// Bin-index dims `(M, C, KY, KX)`.
    pub idx_dims: [usize; 4],
    /// Dictionary bins `B`.
    pub bins: usize,
    /// Output dims `(M, OH, OW)`.
    pub out_dims: [usize; 3],
}

/// A compiled e2e model executable at a fixed batch size.
pub struct ModelExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// The fixed batch size this executable was compiled at.
    pub batch: usize,
    /// Input image dims `(C, H, W)`.
    pub in_dims: [usize; 3],
    /// Output class count.
    pub classes: usize,
}

/// Flat model parameters in manifest order, pre-marshalled.
#[derive(Clone, Debug)]
pub struct ModelParams {
    /// (name, f32 data or i32 data, shape) in `model_param_order`.
    pub entries: Vec<ParamValue>,
}

/// One marshalled parameter.
#[derive(Clone, Debug)]
pub enum ParamValue {
    /// f32 data with its shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 data with its shape.
    I32(Vec<i32>, Vec<usize>),
}

impl Runtime {
    /// Create the CPU client and load the manifest from `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { client, manifest })
    }

    fn compile(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.path_of(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile artifact '{name}'"))
    }

    /// Compile a conv-tile artifact (`pasm_tile`, `ws_tile`).
    pub fn load_tile(&self, name: &str) -> Result<TileExecutable> {
        let t = &self.manifest.tile;
        Ok(TileExecutable {
            exe: self.compile(name)?,
            name: name.to_string(),
            image_dims: [t.channels, t.in_h, t.in_w],
            idx_dims: [t.kernels, t.channels, t.kernel_h, t.kernel_w],
            bins: t.bins,
            out_dims: [t.kernels, t.out_h, t.out_w],
        })
    }

    /// Compile the e2e model at one of the exported batch sizes.
    pub fn load_model(&self, batch: usize) -> Result<ModelExecutable> {
        let m = &self.manifest.model;
        if !m.batch_sizes.contains(&batch) {
            bail!("batch {batch} not exported (available: {:?})", m.batch_sizes);
        }
        Ok(ModelExecutable {
            exe: self.compile(&format!("model_b{batch}"))?,
            batch,
            in_dims: [m.in_c, m.in_h, m.in_w],
            classes: m.classes,
        })
    }
}

fn f32_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(data.len() == n, "literal data/shape mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

fn i32_literal(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(data.len() == n, "literal data/shape mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

impl TileExecutable {
    /// Execute the tile: `image [C,IH,IW] f32`, `bin_idx [M,C,KY,KX]`,
    /// `codebook [B] f32` -> `[M,OH,OW] f32`.
    pub fn run(
        &self,
        image: &Tensor<f32>,
        bin_idx: &Tensor<u16>,
        codebook: &[f32],
    ) -> Result<Tensor<f32>> {
        anyhow::ensure!(image.dims() == self.image_dims, "image dims mismatch");
        anyhow::ensure!(bin_idx.dims() == self.idx_dims, "bin_idx dims mismatch");
        anyhow::ensure!(codebook.len() == self.bins, "codebook length mismatch");

        let img_lit = f32_literal(image.data(), image.dims())?;
        let idx_i32: Vec<i32> = bin_idx.data().iter().map(|&b| b as i32).collect();
        let idx_lit = i32_literal(&idx_i32, bin_idx.dims())?;
        let cb_lit = f32_literal(codebook, &[self.bins])?;

        let result = self.exe.execute::<xla::Literal>(&[img_lit, idx_lit, cb_lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        Ok(Tensor::from_vec(&self.out_dims, values))
    }
}

impl ModelExecutable {
    /// Execute a batch: `images [N,C,H,W]` + params -> logits `[N,classes]`.
    pub fn run(&self, images: &Tensor<f32>, params: &ModelParams) -> Result<Tensor<f32>> {
        let want = [self.batch, self.in_dims[0], self.in_dims[1], self.in_dims[2]];
        anyhow::ensure!(images.dims() == want, "batch images dims mismatch");

        let mut lits: Vec<xla::Literal> = Vec::with_capacity(1 + params.entries.len());
        lits.push(f32_literal(images.data(), images.dims())?);
        for p in &params.entries {
            lits.push(match p {
                ParamValue::F32(data, dims) => f32_literal(data, dims)?,
                ParamValue::I32(data, dims) => i32_literal(data, dims)?,
            });
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        Ok(Tensor::from_vec(&[self.batch, self.classes], values))
    }
}

impl ModelParams {
    /// Marshal an [`crate::cnn::network::EncodedCnn`] into the artifact's
    /// parameter order (bi1, cb1, bias1, bi2, cb2, bias2, dense_w, dense_b).
    pub fn from_encoded(enc: &crate::cnn::network::EncodedCnn) -> Self {
        let idx_i32 = |t: &Tensor<u16>| -> (Vec<i32>, Vec<usize>) {
            (t.data().iter().map(|&b| b as i32).collect(), t.dims().to_vec())
        };
        let (bi1, bi1d) = idx_i32(&enc.conv1.bin_idx);
        let (bi2, bi2d) = idx_i32(&enc.conv2.bin_idx);
        ModelParams {
            entries: vec![
                ParamValue::I32(bi1, bi1d),
                ParamValue::F32(
                    enc.conv1.codebook.values.clone(),
                    vec![enc.conv1.codebook.bins()],
                ),
                ParamValue::F32(enc.conv1_b.clone(), vec![enc.conv1_b.len()]),
                ParamValue::I32(bi2, bi2d),
                ParamValue::F32(
                    enc.conv2.codebook.values.clone(),
                    vec![enc.conv2.codebook.bins()],
                ),
                ParamValue::F32(enc.conv2_b.clone(), vec![enc.conv2_b.len()]),
                ParamValue::F32(enc.dense_w.data().to_vec(), enc.dense_w.dims().to_vec()),
                ParamValue::F32(enc.dense_b.clone(), vec![enc.dense_b.len()]),
            ],
        }
    }
}
