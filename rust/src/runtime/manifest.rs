//! Typed view of `artifacts/manifest.json` (written by `aot.py`).
//!
//! The manifest is the single source of truth for artifact shapes: the
//! rust side never hard-codes model dimensions — it marshals inputs from
//! these specs, so a re-lowered python model propagates automatically.

use crate::runtime::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape/dtype of one model parameter.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    /// Parameter dims, outermost first.
    pub shape: Vec<usize>,
    /// Element dtype name (e.g. "float32", "int32").
    pub dtype: String,
}

/// The paper-tile artifact description.
#[derive(Clone, Debug)]
pub struct TileSpec {
    /// Input channels `C`.
    pub channels: usize,
    /// Input spatial height `IH`.
    pub in_h: usize,
    /// Input spatial width `IW`.
    pub in_w: usize,
    /// Kernel spatial height `KY`.
    pub kernel_h: usize,
    /// Kernel spatial width `KX`.
    pub kernel_w: usize,
    /// Kernel count `M`.
    pub kernels: usize,
    /// Dictionary bins `B`.
    pub bins: usize,
    /// Output spatial height `OH`.
    pub out_h: usize,
    /// Output spatial width `OW`.
    pub out_w: usize,
}

/// The e2e model artifact description.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Input channels.
    pub in_c: usize,
    /// Input spatial height.
    pub in_h: usize,
    /// Input spatial width.
    pub in_w: usize,
    /// Output class count.
    pub classes: usize,
    /// Dictionary bins per conv layer.
    pub bins: usize,
    /// Batch sizes the AOT flow exported executables for.
    pub batch_sizes: Vec<usize>,
    /// Positional parameter order of the exported executables.
    pub param_order: Vec<String>,
    /// Per-parameter shape/dtype specs, by name.
    pub params: BTreeMap<String, ParamSpec>,
}

/// Parsed manifest plus artifact file paths.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// The artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Paper-tile artifact description.
    pub tile: TileSpec,
    /// E2e model artifact description.
    pub model: ModelSpec,
    /// artifact name -> file name
    pub artifacts: BTreeMap<String, String>,
}

fn usize_field(obj: &Json, key: &str) -> Result<usize> {
    obj.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("manifest missing numeric field '{key}'"))
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let root = parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;

        let tile_j = root.get("tile").context("manifest missing 'tile'")?;
        let tile = TileSpec {
            channels: usize_field(tile_j, "channels")?,
            in_h: usize_field(tile_j, "in_h")?,
            in_w: usize_field(tile_j, "in_w")?,
            kernel_h: usize_field(tile_j, "kernel_h")?,
            kernel_w: usize_field(tile_j, "kernel_w")?,
            kernels: usize_field(tile_j, "kernels")?,
            bins: usize_field(tile_j, "bins")?,
            out_h: usize_field(tile_j, "out_h")?,
            out_w: usize_field(tile_j, "out_w")?,
        };

        let model_j = root.get("model").context("manifest missing 'model'")?;
        let param_order: Vec<String> = root
            .get("model_param_order")
            .and_then(Json::as_arr)
            .context("manifest missing 'model_param_order'")?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        let mut params = BTreeMap::new();
        for (k, v) in root
            .get("model_params")
            .and_then(Json::as_obj)
            .context("manifest missing 'model_params'")?
        {
            let shape = v
                .get("shape")
                .and_then(Json::as_arr)
                .context("param missing shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let dtype = v
                .get("dtype")
                .and_then(Json::as_str)
                .context("param missing dtype")?
                .to_string();
            params.insert(k.clone(), ParamSpec { shape, dtype });
        }
        let model = ModelSpec {
            in_c: usize_field(model_j, "in_c")?,
            in_h: usize_field(model_j, "in_h")?,
            in_w: usize_field(model_j, "in_w")?,
            classes: usize_field(model_j, "classes")?,
            bins: usize_field(model_j, "bins")?,
            batch_sizes: model_j
                .get("batch_sizes")
                .and_then(Json::as_arr)
                .context("model missing batch_sizes")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            param_order,
            params,
        };

        let mut artifacts = BTreeMap::new();
        for (k, v) in root
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest missing 'artifacts'")?
        {
            if let Some(f) = v.as_str() {
                artifacts.insert(k.clone(), f.to_string());
            }
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }

        Ok(ArtifactManifest { dir, tile, model, artifacts })
    }

    /// Absolute path of a named artifact.
    pub fn path_of(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        Ok(self.dir.join(file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration with the real artifacts directory (requires
    /// `make artifacts` — run explicitly via `cargo test -- --ignored`).
    #[test]
    #[ignore = "requires `make artifacts`"]
    fn loads_real_manifest() {
        let m = ArtifactManifest::load("artifacts").expect("run `make artifacts` first");
        assert_eq!(m.tile.channels, 15);
        assert_eq!(m.tile.bins, 16);
        assert_eq!(m.tile.out_h, 3);
        assert_eq!(m.model.classes, 10);
        assert_eq!(m.model.param_order.len(), 8);
        assert!(m.model.params.contains_key("dense_w"));
        assert!(m.path_of("pasm_tile").unwrap().exists());
        assert!(m.path_of("model_b8").unwrap().exists());
        assert!(m.path_of("nonexistent").is_err());
    }

    #[test]
    #[ignore = "requires `make artifacts`"]
    fn param_specs_consistent() {
        let m = ArtifactManifest::load("artifacts").expect("run `make artifacts` first");
        let dw = &m.model.params["dense_w"];
        assert_eq!(dw.shape, vec![144, 10]);
        assert_eq!(dw.dtype, "float32");
        let bi1 = &m.model.params["bi1"];
        assert_eq!(bi1.dtype, "int32");
        assert_eq!(bi1.shape.len(), 4);
    }
}
