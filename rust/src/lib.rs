//! # pasm-accel
//!
//! Production-quality reproduction of *"Low Complexity Multiply-Accumulate
//! Units for Convolutional Neural Networks with Weight-Sharing"*
//! (James Garland & David Gregg, 2018).
//!
//! The paper re-architects the multiply-accumulate (MAC) unit of a
//! weight-shared CNN accelerator into **PASM**: a bank of *parallel
//! accumulate-and-store* (PAS) units that scatter image values into `B`
//! dictionary-index bins, followed by a shared post-pass MAC that contracts
//! the bins with the codebook.  For `B ≪ C·KX·KY` this removes the per-tap
//! multiplier — the dominant area/power cost — at a small latency cost.
//!
//! This crate provides the full system around that idea:
//!
//! * [`tensor`] — minimal row-major NdArray substrate (no external deps).
//! * [`quant`] — fixed-point arithmetic and K-means weight sharing
//!   (deep-compression style codebooks).
//! * [`cnn`] — bit-exact functional implementations of the three
//!   accelerator dataflows (direct / weight-shared / PASM) plus a tiny
//!   trainable CNN used by the end-to-end example, and [`cnn::plan`]: the
//!   plan/execute split that compiles an encoded model once
//!   ([`cnn::plan::CompiledCnn`]) so steady-state serving forwards
//!   allocate nothing and skip every per-request weight-state rebuild.
//! * [`hw`] — structural gate, area and power models for a 45 nm ASIC
//!   (NAND2-normalized, FreePDK45-class constants).
//! * [`fpga`] — DSP/BRAM/LUT/FF resource mapping for Zynq-7000 parts.
//! * [`sim`] — cycle-accurate simulator of the MAC / WS-MAC / PAS units and
//!   of whole accelerators, with toggle counting that feeds the power model.
//! * [`accel`] — accelerator variant builder (standalone 16-MAC vs
//!   16-PAS-4-MAC units, full conv-layer accelerators, HLS directive knobs).
//! * [`model_store`] — durable model artifacts and multi-model serving
//!   state: the `.pasm` binary format (per-layer codebooks +
//!   Huffman-coded bin-index streams, fixed-point metadata, CRC-32
//!   integrity; bit-exact `pack`/`load`) and the hot-swappable
//!   [`model_store::ModelRegistry`] (atomic snapshot swaps, lock-free
//!   generation checks, poll-based directory reload) the coordinator
//!   serves many model variants from at once.
//! * [`runtime`] — artifact manifest + JSON layers (always built) and, behind
//!   the `pjrt` cargo feature, the PJRT CPU client that loads the AOT-lowered
//!   JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and executes them on the
//!   request path (python never runs at inference time).
//! * [`coordinator`] — sharded inference coordinator (std threads +
//!   channels; no async runtime in the offline build): a pool of N
//!   independent batching workers routed by a stable hash of the model
//!   id, each with per-model request queues, a bucketed dynamic batcher,
//!   a pluggable [`coordinator::backend`] execution substrate
//!   (compiled-plan native kernels with a parallel batch worker pool, or
//!   PJRT) with per-model executables keyed by registry generation, a
//!   hardware [`coordinator::cost`] model, and shard-local per-model
//!   metrics merged on snapshot.
//! * [`serving`] — the network front-end: a length-prefixed JSON wire
//!   protocol ([`serving::proto`], spec in `docs/WIRE_PROTOCOL.md`), a
//!   thread-per-connection TCP server with admission control
//!   ([`serving::net`]), and a blocking client ([`serving::client`])
//!   with bounded, seeded-jitter retries.
//! * [`obs`] — observability primitives: mergeable log-bucketed latency
//!   histograms ([`obs::LogHistogram`], bounded memory, exact-within-bucket
//!   percentiles) and lock-free per-shard request-lifecycle trace rings
//!   ([`obs::TraceBuf`]), threaded through the coordinator and both
//!   front-ends and exported over the wire (`metrics` / `trace` frames).
//! * [`faults`] — deterministic fault injection ([`faults::FaultPlan`]):
//!   seeded schedules of batch panics, execution errors, injected
//!   latency, shard-worker kills, torn artifact loads, and socket
//!   resets, always compiled in and inert when unset — the harness the
//!   chaos e2e uses to prove the failure paths.
//! * [`report`] — regenerates every table and figure of the paper's
//!   evaluation section.
//!
//! See `docs/ARCHITECTURE.md` for the end-to-end request path and model
//! lifecycle, `rust/README.md` for the layer map, and `ROADMAP.md` for
//! where this is headed.

#![warn(missing_docs)]

pub mod accel;
pub mod cnn;
pub mod coordinator;
pub mod faults;
pub mod fpga;
pub mod hw;
pub mod model_store;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod tensor;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
