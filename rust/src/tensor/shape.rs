//! Shape algebra for dense tensors and convolution windows.

use std::fmt;

/// A dense row-major shape (up to arbitrary rank; conv code uses rank 3/4).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Shape(
    /// The dimension sizes, outermost first.
    pub Vec<usize>,
);

impl Shape {
    /// A shape with the given dimension sizes.
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// The dimension sizes, outermost first.
    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Total element count (product of dims; empty shape is a scalar = 1).
    pub fn len(&self) -> usize {
        self.0.iter().product()
    }

    /// Whether the shape has zero volume (some dim is 0).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Row-major strides (innermost dim has stride 1).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.0.len()];
        for i in (0..self.0.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.0[i + 1];
        }
        s
    }

    /// Flatten a multi-index to a linear offset. Panics on rank mismatch or
    /// out-of-bounds in debug builds.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.0.len(), "index rank mismatch");
        let mut off = 0usize;
        let mut stride = 1usize;
        for d in (0..self.0.len()).rev() {
            debug_assert!(idx[d] < self.0[d], "index out of bounds");
            off += idx[d] * stride;
            stride *= self.0[d];
        }
        off
    }
}

impl fmt::Debug for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.0)
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let inner: Vec<String> = self.0.iter().map(|d| d.to_string()).collect();
        write!(f, "[{}]", inner.join(","))
    }
}

impl From<&[usize]> for Shape {
    fn from(d: &[usize]) -> Self {
        Shape(d.to_vec())
    }
}

/// Output spatial dim of a VALID convolution: `(in - k) / stride + 1`.
///
/// Matches the paper's Fig 1 loop bounds (the kernel center sweeps
/// `[K/2, IH - K/2)` at the given stride, which visits exactly this many
/// positions for odd K; we use the standard VALID form for all K).
pub fn conv_out_dim(input: usize, kernel: usize, stride: usize) -> usize {
    assert!(kernel >= 1 && stride >= 1, "kernel/stride must be >= 1");
    assert!(input >= kernel, "input {input} smaller than kernel {kernel}");
    (input - kernel) / stride + 1
}

/// Full shape description of one convolution layer (paper Fig 1 names).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvShape {
    /// Input channels `C`.
    pub channels: usize,
    /// Input spatial height `IH`.
    pub in_h: usize,
    /// Input spatial width `IW`.
    pub in_w: usize,
    /// Kernel spatial height `KY`.
    pub kernel_h: usize,
    /// Kernel spatial width `KX`.
    pub kernel_w: usize,
    /// Output channels (number of kernels) `M`.
    pub kernels: usize,
    /// Stride `S`.
    pub stride: usize,
}

impl ConvShape {
    /// A validated conv shape (panics on degenerate dimensions).
    pub fn new(
        channels: usize,
        in_h: usize,
        in_w: usize,
        kernel_h: usize,
        kernel_w: usize,
        kernels: usize,
        stride: usize,
    ) -> Self {
        let s = ConvShape { channels, in_h, in_w, kernel_h, kernel_w, kernels, stride };
        s.validate();
        s
    }

    /// The paper's §4 accelerator tile: IH=IW=5, C=15, KY=KX=3, M=2, S=1.
    pub fn paper_tile() -> Self {
        Self::new(15, 5, 5, 3, 3, 2, 1)
    }

    /// Panic unless the dimensions describe a runnable VALID convolution.
    pub fn validate(&self) {
        assert!(self.channels >= 1 && self.kernels >= 1);
        assert!(self.in_h >= self.kernel_h && self.in_w >= self.kernel_w);
        assert!(self.stride >= 1);
    }

    /// Output spatial height `OH`.
    pub fn out_h(&self) -> usize {
        conv_out_dim(self.in_h, self.kernel_h, self.stride)
    }

    /// Output spatial width `OW`.
    pub fn out_w(&self) -> usize {
        conv_out_dim(self.in_w, self.kernel_w, self.stride)
    }

    /// Output pixels per kernel plane: `OH * OW`.
    pub fn out_pixels(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// MAC operations per output element: `N = C * KY * KX` (paper §4,
    /// Table 2 — the quantity that must dominate `B` for PASM to win).
    pub fn taps(&self) -> usize {
        self.channels * self.kernel_h * self.kernel_w
    }

    /// Total MAC operations in the layer: `M * OH * OW * taps`.
    pub fn total_macs(&self) -> usize {
        self.kernels * self.out_pixels() * self.taps()
    }

    /// Input image shape `[C, IH, IW]`.
    pub fn image_shape(&self) -> Shape {
        Shape::new(&[self.channels, self.in_h, self.in_w])
    }

    /// Weight tensor shape `[M, C, KY, KX]`.
    pub fn weight_shape(&self) -> Shape {
        Shape::new(&[self.kernels, self.channels, self.kernel_h, self.kernel_w])
    }

    /// Output feature-map shape `[M, OH, OW]`.
    pub fn out_shape(&self) -> Shape {
        Shape::new(&[self.kernels, self.out_h(), self.out_w()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(s.len(), 24);
    }

    #[test]
    fn offset_roundtrip() {
        let s = Shape::new(&[3, 5, 7]);
        let mut seen = vec![false; s.len()];
        for i in 0..3 {
            for j in 0..5 {
                for k in 0..7 {
                    let off = s.offset(&[i, j, k]);
                    assert!(!seen[off]);
                    seen[off] = true;
                }
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn conv_out_dims() {
        assert_eq!(conv_out_dim(5, 3, 1), 3);
        assert_eq!(conv_out_dim(12, 3, 1), 10);
        assert_eq!(conv_out_dim(9, 3, 2), 4);
        assert_eq!(conv_out_dim(3, 3, 1), 1);
    }

    #[test]
    #[should_panic]
    fn conv_out_dim_too_small() {
        conv_out_dim(2, 3, 1);
    }

    #[test]
    fn paper_tile_counts() {
        let t = ConvShape::paper_tile();
        assert_eq!(t.out_h(), 3);
        assert_eq!(t.out_w(), 3);
        assert_eq!(t.taps(), 135); // 15 * 3 * 3
        assert_eq!(t.total_macs(), 2 * 9 * 135);
    }

    /// Table 2 of the paper: MAC ops per output for C x KxK.
    #[test]
    fn table2_values() {
        let cases = [
            (32, 1, 32),
            (128, 1, 128),
            (512, 1, 512),
            (32, 3, 288),
            (128, 3, 1152),
            (512, 3, 4608),
            (32, 5, 800),
            (128, 5, 3200),
            (512, 5, 12800),
            (32, 7, 1568),
            (128, 7, 6272),
            (512, 7, 25088),
        ];
        for (c, k, want) in cases {
            let shape = ConvShape::new(c, k, k, k, k, 1, 1);
            assert_eq!(shape.taps(), want, "C={c} K={k}");
        }
    }

    #[test]
    fn display() {
        assert_eq!(Shape::new(&[2, 3]).to_string(), "[2,3]");
    }
}
