//! Dense row-major tensor with the small op set the conv dataflows need.

use super::shape::Shape;
use std::fmt;
use std::ops::{Add, Mul};

/// A dense row-major tensor over element type `T`.
///
/// `T = f32` carries the trained model; `T = i64` carries the bit-exact
/// fixed-point dataflow that mirrors the hardware accumulators (wide enough
/// to hold a W=32 multiply plus log2(C*K*K) accumulation bits).
#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Shape,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-filled (default-filled) tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let shape = Shape::new(shape);
        let data = vec![T::default(); shape.len()];
        Tensor { shape, data }
    }

    /// Build from existing data; panics unless `data.len() == shape.len()`.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        let shape = Shape::new(shape);
        assert_eq!(
            data.len(),
            shape.len(),
            "data length {} != shape volume {}",
            data.len(),
            shape.len()
        );
        Tensor { shape, data }
    }

    /// Fill with a function of the linear index.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> T) -> Self {
        let shape = Shape::new(shape);
        let data = (0..shape.len()).map(&mut f).collect();
        Tensor { shape, data }
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// The dimension sizes, outermost first.
    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Total element count (the shape's volume).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The elements in row-major order.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Mutable view of the elements in row-major order.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume the tensor, returning its row-major elements.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Element at a multi-dimensional index; panics out of bounds.
    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.shape.offset(idx)]
    }

    /// Mutable element at a multi-dimensional index; panics out of bounds.
    #[inline]
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut T {
        let off = self.shape.offset(idx);
        &mut self.data[off]
    }

    /// Reshape in place (volume-preserving view change).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let new = Shape::new(shape);
        assert_eq!(new.len(), self.shape.len(), "reshape changes volume");
        self.shape = new;
        self
    }

    /// Map every element through `f`, possibly changing element type.
    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

impl<T: Copy + Default + Add<Output = T>> Tensor<T> {
    /// Element-wise sum; shapes must match.
    pub fn add(&self, other: &Tensor<T>) -> Tensor<T> {
        assert_eq!(self.shape, other.shape, "shape mismatch in add");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| a + b)
                .collect(),
        }
    }
}

impl<T> Tensor<T>
where
    T: Copy + Default + Add<Output = T> + Mul<Output = T>,
{
    /// `self [R, K] @ other [K, C] -> [R, C]` plain matmul (reference path;
    /// the simulator and the hot loops never call this on large shapes).
    pub fn matmul(&self, other: &Tensor<T>) -> Tensor<T> {
        assert_eq!(self.shape.rank(), 2);
        assert_eq!(other.shape.rank(), 2);
        let (r, k) = (self.dims()[0], self.dims()[1]);
        let (k2, c) = (other.dims()[0], other.dims()[1]);
        assert_eq!(k, k2, "inner dims mismatch");
        let mut out = Tensor::zeros(&[r, c]);
        for i in 0..r {
            for l in 0..k {
                let a = self.data[i * k + l];
                let row = &other.data[l * c..(l + 1) * c];
                let dst = &mut out.data[i * c..(i + 1) * c];
                for (d, &b) in dst.iter_mut().zip(row) {
                    *d = *d + a * b;
                }
            }
        }
        out
    }
}

impl Tensor<f32> {
    /// Maximum absolute element-wise difference (test helper).
    pub fn max_abs_diff(&self, other: &Tensor<f32>) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// All elements finite?
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl<T: fmt::Debug + Copy + Default> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?} ", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, "{:?}", self.data)
        } else {
            write!(f, "[{:?}, ... {} elems]", &self.data[..8], self.data.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_indexing() {
        let mut t = Tensor::<i64>::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        *t.at_mut(&[1, 2]) = 42;
        assert_eq!(t.at(&[1, 2]), 42);
        assert_eq!(t.at(&[0, 0]), 0);
        assert_eq!(t.data()[5], 42);
    }

    #[test]
    fn from_fn_linear_order() {
        let t = Tensor::<i64>::from_fn(&[2, 2], |i| i as i64);
        assert_eq!(t.data(), &[0, 1, 2, 3]);
        assert_eq!(t.at(&[1, 0]), 2);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1i64, 2, 3, 4]);
        let b = Tensor::from_vec(&[2, 2], vec![1i64, 1, 1, 1]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3, 3, 7, 7]);
    }

    #[test]
    fn map_changes_type() {
        let a = Tensor::from_vec(&[3], vec![1.5f32, -2.5, 0.0]);
        let b = a.map(|x| x as i64);
        assert_eq!(b.data(), &[1, -2, 0]);
    }

    #[test]
    fn add_elementwise() {
        let a = Tensor::from_vec(&[2], vec![1i64, 2]);
        let b = Tensor::from_vec(&[2], vec![10i64, 20]);
        assert_eq!(a.add(&b).data(), &[11, 22]);
    }

    #[test]
    #[should_panic]
    fn from_vec_len_mismatch() {
        Tensor::from_vec(&[2, 2], vec![1i64]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6i64).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.dims(), &[3, 2]);
        assert_eq!(r.data(), t.data());
    }
}
