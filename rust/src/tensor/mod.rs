//! Minimal row-major tensor substrate.
//!
//! The paper's dataflows operate on small dense tensors (`image[C][IH][IW]`,
//! `weight[M][C][KY][KX]`, `outFeat[M][OH][OW]` — Fig 1).  This module
//! provides exactly the NdArray machinery those loops need — shapes,
//! strides, windowed views, im2col — with no external dependencies, for any
//! element type (f32 for training, `i64` for the bit-exact fixed-point
//! dataflow the hardware simulator checks against).

mod ndarray;
mod shape;

pub use ndarray::Tensor;
pub use shape::{conv_out_dim, ConvShape, Shape};
