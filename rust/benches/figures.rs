//! `cargo bench --bench figures` — regenerate every paper exhibit and time
//! the regeneration (each iteration rebuilds the full model-driven report,
//! proving the whole evaluation is reproducible in seconds, not tool-days).
//!
//! Output doubles as the paper-vs-measured record: the rendered reports are
//! printed once, followed by the timings.

use pasm_accel::report::bench::{bench, black_box};
use pasm_accel::report::{all_report_ids, run_report};
use std::time::Duration;

fn main() {
    // 1) print every exhibit once (this is the reproduction artifact)
    for id in all_report_ids() {
        let r = run_report(id).expect("report");
        println!("{}", r.render());
    }

    // 2) time each regeneration
    println!("--- regeneration timings ---");
    for id in all_report_ids() {
        let r = bench(&format!("report/{id}"), Duration::from_millis(200), 16, || {
            black_box(run_report(id).unwrap());
        });
        r.print();
    }

    // 3) the full suite end-to-end
    let r = bench("report/all", Duration::from_millis(500), 8, || {
        for id in all_report_ids() {
            black_box(run_report(id).unwrap());
        }
    });
    r.print();
}
