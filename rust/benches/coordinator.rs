//! `cargo bench --bench coordinator` — end-to-end serving benchmark: the
//! paper's system serving batched inference through the configured
//! execution backend (native reference kernels by default; the
//! PJRT-compiled PASM model with `--features pjrt` after `make artifacts`).
//! Reports request throughput, latency percentiles, batch occupancy, and
//! the simulated accelerator cost per request.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{default_backend, BatchPolicy, CoordinatorBuilder};
use pasm_accel::quant::fixed::QFormat;
use std::time::{Duration, Instant};

fn main() {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(3);
    let params = arch.init(&mut rng);
    let enc = EncodedCnn::encode(arch, &params, 16, QFormat::W32);

    let coord = CoordinatorBuilder::new()
        .boxed_backend(default_backend("artifacts", enc))
        .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2)))
        .build()
        .expect("coordinator startup");
    println!("backend: {}", coord.metrics().backend);

    // pre-render a request pool
    let pool: Vec<_> = (0..256)
        .map(|i| render_digit(&mut rng, i % 10, 0.05))
        .collect();

    for load in [64usize, 256, 1024] {
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..load)
            .map(|i| coord.submit(pool[i % pool.len()].clone()).unwrap())
            .collect();
        let mut ok = 0usize;
        for rx in rxs {
            if rx.recv().unwrap().is_ok() {
                ok += 1;
            }
        }
        let dt = t0.elapsed();
        assert_eq!(ok, load);
        println!(
            "bench coordinator/serve_{load}: {:?} total, {:.1} req/s",
            dt,
            load as f64 / dt.as_secs_f64()
        );
    }

    let m = coord.metrics();
    println!(
        "batches {} | mean occupancy {:.2} | padding {:.1}%",
        m.batches,
        m.mean_occupancy(),
        m.padding_fraction() * 100.0
    );
    for p in [50.0, 90.0, 99.0] {
        println!("p{p:.0} latency: {} us", m.percentile_us(p).unwrap());
    }
    println!(
        "simulated accelerator totals: {} cycles, {:.3} uJ",
        m.sim_cycles,
        m.sim_energy_j * 1e6
    );
}
