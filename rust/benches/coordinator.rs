//! `cargo bench --bench coordinator` — end-to-end serving benchmark: the
//! paper's system serving batched fixed-point inference through the native
//! backend.  The served model travels the full production path first —
//! packed to a `.pasm` artifact, loaded back through a
//! [`pasm_accel::model_store::ModelRegistry`], verified bit-identical to
//! the in-memory source — and two configurations then run back to back on
//! identical numerics:
//!
//! * `baseline` — the pre-plan execution strategy (per-request
//!   `FxConvInputs` encode, serial batch rows; what the serving path did
//!   before the compiled-plan rework), via `NativeBackend::with_plan(false)`.
//! * `planned` — the compiled-plan path serving the **registry-loaded**
//!   model: requests route by model id through the multi-model engine,
//!   `CompiledCnn` built once at startup, rows sharded across the worker
//!   pool.
//!
//! Results print to stdout, and `BENCH_serving.json` at the repository
//! root is **rewritten** with this run's machine-readable results (req/s,
//! latency percentiles, occupancy, backend label, and the artifact's
//! bytes-on-disk vs raw-f32 compression ratio — the paper's §2.1
//! headline) — the perf trajectory across PRs lives in the committed
//! history of that file, one snapshot per run.
//!
//! A third phase drives the same registry-served model **over real TCP
//! sockets**: a `serving::net::Server` on an ephemeral port, loaded by
//! `loadgen::run_open_loop_net` at ~70% of the planned path's measured
//! capacity.  Its req/s and latency percentiles land in the `net`
//! section of `BENCH_serving.json`, next to the in-process numbers, so
//! the wire + framing overhead stays visible across PRs.
//!
//! A fourth phase measures **coordinator sharding**: four model variants
//! under the same open-loop schedule, served by a 1-shard and then a
//! 4-shard pool (one execution thread per shard, so the shard count is
//! the parallelism axis).  Merged req/s and per-shard batch counts land
//! in the `shards` section; `shard_comparison` holds the 1-vs-4 speedup.
//!
//! A fifth phase compares the two **serving front-ends**: the same
//! open-loop socket loads run against the thread-per-connection server
//! and the evented readiness-loop server back to back (`net` entries
//! carry a `server` field; `frontend_comparison` holds the ratio at the
//! top load).  A sixth phase isolates **protocol pipelining**: one
//! connection drives the evented server closed-loop with a window of 1
//! (serial) and then a window of 32, and the run *asserts* the
//! pipelined leg beats the serial leg — that claim is the acceptance
//! bar, so it fails the bench rather than silently recording a
//! regression.
//!
//! A seventh phase prices **observability**: the planned path runs with
//! request-lifecycle tracing off (`trace_capacity(0)` — no ring, no
//! recording) and on (the default per-shard ring), alternating
//! best-of-3, and the run *asserts* tracing keeps at least 98% of the
//! untraced throughput — the ≤2% overhead claim in
//! `docs/ARCHITECTURE.md` is an acceptance bar, not prose.  The merged
//! per-stage latency histograms (queue wait, batch formation, execute,
//! write-back) harvested from the threaded socket phase land in the
//! `stages` section, and the on/off comparison in `trace_overhead`.
//!
//! An eighth phase compares the two **PASM execution kernels**: the same
//! fixed-point model served with per-tap plans and then with
//! histogram-accumulate (count-then-multiply) plans, at several codebook
//! sizes, one execution thread, best-of-2 alternating — after a
//! bit-equality cross-check of both kernels' served logits against the
//! reference `forward_fx`.  Per-B req/s for both kernels land in the
//! `kernels` section, making the paper's §5.3 trick a *measured* CPU
//! number rather than a claim.
//!
//! A ninth phase measures **hot-model elasticity**: a Zipf-skewed
//! multi-tenant open-loop load (8 model variants, the head of the law
//! drawing the majority of traffic) against the same 4-shard pool with
//! cross-shard batch stealing off and then on.  The offered rate is set
//! so the hot model alone outruns its home shard while the pool retains
//! idle capacity — exactly the skew stealing exists to absorb.  Before
//! any timing, hot-model logits served through the stolen path (eager
//! donation) are bit-compared against the reference `forward_fx`.  The
//! hot model's per-model throughput in both legs, the steal/replica
//! counters, and per-shard occupancy under skew land in the
//! `elasticity` section; the full (non-smoke) run *asserts* the hot
//! model's ceiling lifts by at least 1.4x with stealing on.
//!
//! The bench never writes placeholders: every section is validated as
//! measured (non-empty, positive req/s) before `BENCH_serving.json` is
//! rewritten, and any shortfall panics the run (non-zero exit) instead
//! of committing a file that looks like data.
//!
//! `--smoke` serves only the smallest load (the CI perf-harness check);
//! the resulting file's `comparison.load` is 64, not the 1024 the
//! acceptance bar reads — don't commit a smoke file over a full run.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::cnn::plan::KernelChoice;
#[cfg(unix)]
use pasm_accel::coordinator::loadgen::run_closed_loop_pipelined;
use pasm_accel::coordinator::loadgen::{
    DEFAULT_REQUEST_TIMEOUT, NetLoadOptions, ZipfOptions, run_open_loop_models, run_open_loop_net,
    run_open_loop_zipf,
};
use pasm_accel::coordinator::{
    BatchPolicy, Coordinator, CoordinatorBuilder, NativeBackend, NativePrecision,
};
use pasm_accel::model_store::{self, ModelRegistry};
use pasm_accel::obs::DEFAULT_TRACE_CAPACITY;
use pasm_accel::quant::fixed::QFormat;
#[cfg(unix)]
use pasm_accel::serving::{EventedConfig, EventedServer};
use pasm_accel::serving::{Server, ServerConfig};
use pasm_accel::tensor::Tensor;
use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

const JSON_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
const MODEL: &str = "digits";

struct RunStats {
    config: &'static str,
    backend: String,
    load: usize,
    req_s: f64,
    p50_us: u64,
    p90_us: u64,
    p99_us: u64,
    mean_occupancy: f64,
    padding_fraction: f64,
    batches: u64,
}

struct NetStats {
    server: &'static str,
    load: usize,
    offered_hz: f64,
    req_s: f64,
    p50_us: u64,
    p90_us: u64,
    p99_us: u64,
    overloaded: usize,
    errors: usize,
}

struct PipelineStats {
    requests: usize,
    depth: usize,
    window: usize,
    serial_req_s: f64,
    pipelined_req_s: f64,
}

struct ShardStats {
    shards: usize,
    models: usize,
    load: usize,
    offered_hz: f64,
    req_s: f64,
    p50_us: u64,
    p99_us: u64,
    per_shard_batches: Vec<u64>,
}

struct StageStat {
    stage: &'static str,
    count: u64,
    p50_us: u64,
    p99_us: u64,
    mean_us: f64,
}

struct TraceOverheadStats {
    load: usize,
    off_req_s: f64,
    on_req_s: f64,
}

impl TraceOverheadStats {
    fn ratio(&self) -> f64 {
        self.on_req_s / self.off_req_s
    }
}

struct KernelStats {
    bins: usize,
    load: usize,
    conv2_taps: usize,
    per_tap_req_s: f64,
    histogram_req_s: f64,
}

struct ElasticityStats {
    shards: usize,
    models: usize,
    load: usize,
    zipf_s: f64,
    offered_hz: f64,
    hot_off_req_s: f64,
    hot_on_req_s: f64,
    total_off_req_s: f64,
    total_on_req_s: f64,
    stolen_batches: u64,
    donated_batches: u64,
    replicas_installed: u64,
    per_shard_batches_on: Vec<u64>,
    per_shard_stolen_on: Vec<u64>,
}

impl ElasticityStats {
    fn hot_lift(&self) -> f64 {
        self.hot_on_req_s / self.hot_off_req_s
    }
}

struct ArtifactStats {
    file_bytes: u64,
    raw_f32_bytes: u64,
}

impl ArtifactStats {
    fn ratio(&self) -> f64 {
        self.raw_f32_bytes as f64 / self.file_bytes as f64
    }
}

/// Pack the model into a temp models dir and load it back through a
/// registry — the serving path a production deployment takes.
fn pack_into_registry(enc: &EncodedCnn) -> (Arc<ModelRegistry>, ArtifactStats, PathBuf) {
    let dir = std::env::temp_dir().join(format!("pasm_bench_models_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create bench models dir");
    let file_bytes =
        model_store::save_file(&dir.join(format!("{MODEL}.pasm")), enc).expect("pack model");
    let registry = Arc::new(ModelRegistry::load_dir(&dir).expect("load models dir"));
    let stats = ArtifactStats { file_bytes, raw_f32_bytes: model_store::raw_dense_bytes(enc) };
    (registry, stats, dir)
}

fn build(enc: EncodedCnn, planned: bool, registry: Option<&Arc<ModelRegistry>>) -> Coordinator {
    build_traced(enc, planned, registry, DEFAULT_TRACE_CAPACITY)
}

fn build_traced(
    enc: EncodedCnn,
    planned: bool,
    registry: Option<&Arc<ModelRegistry>>,
    trace_capacity: usize,
) -> Coordinator {
    let backend =
        NativeBackend::new(enc).with_precision(NativePrecision::Fixed(QFormat::IMAGE32));
    let backend = if planned {
        backend
    } else {
        // the pre-plan serving strategy: no compiled plan, serial rows
        backend.with_plan(false).with_threads(1)
    };
    let mut builder = CoordinatorBuilder::new()
        .backend(backend)
        .trace_capacity(trace_capacity)
        .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2)));
    if let Some(reg) = registry {
        // unnamed requests route to the registry model by id: the
        // multi-model engine path, per-model executables and all
        builder = builder.registry(Arc::clone(reg)).default_model(MODEL);
    }
    builder.build().expect("coordinator startup")
}

fn run_load(
    config: &'static str,
    coord: &Coordinator,
    load: usize,
    pool: &[Tensor<f32>],
) -> RunStats {
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..load)
        .map(|i| coord.submit(pool[i % pool.len()].clone()).unwrap())
        .collect();
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv().unwrap().is_ok() {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    assert_eq!(ok, load);
    let m = coord.metrics();
    let req_s = load as f64 / dt.as_secs_f64();
    println!(
        "bench coordinator/{config}/serve_{load}: {dt:?} total, {req_s:.1} req/s, \
         occupancy {:.2}, padding {:.1}%, p99 {} us",
        m.mean_occupancy(),
        m.padding_fraction() * 100.0,
        m.percentile_us(99.0).unwrap()
    );
    RunStats {
        config,
        backend: m.backend.clone(),
        load,
        req_s,
        p50_us: m.percentile_us(50.0).unwrap(),
        p90_us: m.percentile_us(90.0).unwrap(),
        p99_us: m.percentile_us(99.0).unwrap(),
        mean_occupancy: m.mean_occupancy(),
        padding_fraction: m.padding_fraction(),
        batches: m.batches,
    }
}

/// The registry-served planned path must be bit-identical to the source
/// model's reference fixed-point forward — pack → load → serve proves the
/// artifact chain before any throughput number means anything.
fn verify_bitexact(source: &EncodedCnn, registry: &Arc<ModelRegistry>, pool: &[Tensor<f32>]) {
    let loaded = registry.get(MODEL).expect("registry model");
    let coord = build((*loaded.enc).clone(), true, Some(registry));
    for img in pool.iter().take(8) {
        let resp = coord.infer(img.clone()).expect("verification inference");
        assert_eq!(resp.model.as_deref(), Some(MODEL));
        let want = source.forward_fx(img, ConvVariant::Pasm, QFormat::IMAGE32);
        let got: Vec<u32> = resp.logits.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, wb, "registry-served logits diverged from the source model");
    }
    println!("verified: packed+registry-served logits bit-identical to source forward_fx");
}

/// Either bench server kind behind one address; holding the handle keeps
/// the server alive for the measurement and drops it cleanly after.
enum BenchServer {
    Threaded(Server),
    #[cfg(unix)]
    Evented(EventedServer),
}

impl BenchServer {
    fn bind(kind: &str, coord: &Arc<Coordinator>) -> Option<BenchServer> {
        match kind {
            "threaded" => {
                let server = Server::bind("127.0.0.1:0", Arc::clone(coord), ServerConfig::default())
                    .expect("bind threaded bench server");
                Some(BenchServer::Threaded(server))
            }
            #[cfg(unix)]
            "evented" => {
                let server =
                    EventedServer::bind("127.0.0.1:0", Arc::clone(coord), EventedConfig::default())
                        .expect("bind evented bench server");
                Some(BenchServer::Evented(server))
            }
            _ => None,
        }
    }

    fn addr(&self) -> String {
        match self {
            BenchServer::Threaded(s) => s.local_addr().to_string(),
            #[cfg(unix)]
            BenchServer::Evented(s) => s.local_addr().to_string(),
        }
    }
}

/// Socket-path phase: front the registry-served planned coordinator with
/// a TCP server (`kind` selects the threaded or the evented front-end)
/// on an ephemeral port and replay an open-loop Poisson schedule at ~70%
/// of the planned path's measured capacity at each load — under capacity
/// on purpose, so the number reflects wire + framing overhead rather
/// than queueing collapse.  Returns nothing when `kind` is unavailable
/// on this platform (evented is unix-only).
///
/// Also returns the coordinator's merged per-stage latency histograms
/// after the loads — the socket phase is the only one where all four
/// stages (including front-end write-back) carry real samples.
fn run_net_loads(
    kind: &'static str,
    loaded: &EncodedCnn,
    registry: &Arc<ModelRegistry>,
    runs: &[RunStats],
    loads: &[usize],
    pool: &[Tensor<f32>],
) -> (Vec<NetStats>, Vec<StageStat>) {
    let coord = Arc::new(build(loaded.clone(), true, Some(registry)));
    let Some(server) = BenchServer::bind(kind, &coord) else {
        return (Vec::new(), Vec::new());
    };
    let addr = server.addr();
    let mut rng = Rng::new(31);
    let mut stats = Vec::new();
    for &load in loads {
        let planned_req_s = runs
            .iter()
            .find(|r| r.config == "planned" && r.load == load)
            .map(|r| r.req_s)
            .unwrap_or(500.0);
        let rate = (planned_req_s * 0.7).max(50.0);
        let opts = NetLoadOptions { connections: load.clamp(1, 8), ..NetLoadOptions::default() };
        let conns = opts.connections;
        let r = run_open_loop_net(&addr, &[], pool, load, rate, opts, &mut rng)
            .expect("net load run");
        assert_eq!(r.errors, 0, "net bench requests failed");
        let pct = |p| r.percentile_us(p).expect("net bench measured no latencies");
        println!(
            "bench coordinator/net-{kind}/serve_{load}: offered {:.1} req/s, \
             achieved {:.1} req/s, p99 {} us ({} overloaded)",
            r.offered_hz,
            r.achieved_hz,
            pct(99.0),
            r.overloaded
        );
        stats.push(NetStats {
            server: kind,
            load,
            offered_hz: r.offered_hz,
            req_s: r.achieved_hz,
            p50_us: pct(50.0),
            p90_us: pct(90.0),
            p99_us: pct(99.0),
            overloaded: r.overloaded,
            errors: r.errors,
        });
    }
    let stages = summarize_stages(&coord.metrics());
    (stats, stages)
}

/// Collapse the coordinator's merged per-stage histograms into the
/// summary rows the JSON artifact records.
fn summarize_stages(m: &pasm_accel::coordinator::Metrics) -> Vec<StageStat> {
    m.stages
        .named()
        .into_iter()
        .map(|(name, h)| StageStat {
            stage: name,
            count: h.count(),
            p50_us: h.percentile_us(50.0).unwrap_or(0),
            p99_us: h.percentile_us(99.0).unwrap_or(0),
            mean_us: h.mean_us().unwrap_or(0.0),
        })
        .collect()
}

/// Observability-overhead phase: the identical planned-path in-process
/// load with lifecycle tracing disabled (`trace_capacity(0)` — no ring
/// allocated, recording never runs) and enabled (the default per-shard
/// ring), alternated best-of-3 so machine noise doesn't decide a 2%
/// gate.  **Asserts** the traced run keeps ≥98% of the untraced
/// throughput — the overhead bound `docs/ARCHITECTURE.md` promises.
fn run_trace_overhead(
    loaded: &EncodedCnn,
    registry: &Arc<ModelRegistry>,
    load: usize,
    pool: &[Tensor<f32>],
) -> TraceOverheadStats {
    let mut best = [0.0f64; 2]; // [tracing off, tracing on]
    for _ in 0..3 {
        for (slot, capacity) in [(0usize, 0usize), (1, DEFAULT_TRACE_CAPACITY)] {
            let coord = build_traced(loaded.clone(), true, Some(registry), capacity);
            let t0 = Instant::now();
            let rxs: Vec<_> = (0..load)
                .map(|i| coord.submit(pool[i % pool.len()].clone()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap().expect("trace overhead inference failed");
            }
            let req_s = load as f64 / t0.elapsed().as_secs_f64();
            best[slot] = best[slot].max(req_s);
        }
    }
    let stats = TraceOverheadStats { load, off_req_s: best[0], on_req_s: best[1] };
    println!(
        "bench coordinator/trace-overhead/serve_{load}: off {:.1} req/s, \
         on {:.1} req/s ({:.1}% of untraced)",
        stats.off_req_s,
        stats.on_req_s,
        stats.ratio() * 100.0
    );
    assert!(
        stats.ratio() >= 0.98,
        "lifecycle tracing cost {:.1}% throughput (on {:.1} vs off {:.1} req/s) — \
         the observability layer promises <= 2%",
        (1.0 - stats.ratio()) * 100.0,
        stats.on_req_s,
        stats.off_req_s
    );
    stats
}

/// Protocol-pipelining phase: one connection, closed loop, against the
/// evented server — a serial window of 1, then a pipelined window of
/// `depth`.  Everything else (model, coordinator, socket, frames) is
/// identical, so the ratio is what pipelined mode itself buys by
/// amortizing round trips over the window.  **Asserts** the pipelined
/// leg wins: that is the PR's acceptance claim, and a bench that can't
/// demonstrate it should fail, not record it.
#[cfg(unix)]
fn run_pipeline_comparison(
    loaded: &EncodedCnn,
    registry: &Arc<ModelRegistry>,
    requests: usize,
    depth: usize,
    pool: &[Tensor<f32>],
) -> Option<PipelineStats> {
    let coord = Arc::new(build(loaded.clone(), true, Some(registry)));
    let server = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord), EventedConfig::default())
        .expect("bind evented bench server");
    let addr = server.local_addr().to_string();
    let serial =
        run_closed_loop_pipelined(&addr, None, pool, requests, 1).expect("serial closed loop");
    let piped =
        run_closed_loop_pipelined(&addr, None, pool, requests, depth).expect("pipelined loop");
    assert_eq!(serial.errors + piped.errors, 0, "pipeline bench requests failed");
    println!(
        "bench coordinator/pipeline/serve_{requests}: serial {:.1} req/s, \
         pipelined(window {}) {:.1} req/s ({:.2}x)",
        serial.req_per_s,
        piped.window,
        piped.req_per_s,
        piped.req_per_s / serial.req_per_s
    );
    assert!(
        piped.window >= 16,
        "server granted window {} — the comparison needs depth >= 16",
        piped.window
    );
    assert!(
        piped.req_per_s > serial.req_per_s,
        "pipelined (depth {}) {:.1} req/s did not beat serial {:.1} req/s on one connection",
        piped.window,
        piped.req_per_s,
        serial.req_per_s
    );
    Some(PipelineStats {
        requests,
        depth,
        window: piped.window,
        serial_req_s: serial.req_per_s,
        pipelined_req_s: piped.req_per_s,
    })
}

#[cfg(not(unix))]
fn run_pipeline_comparison(
    _loaded: &EncodedCnn,
    _registry: &Arc<ModelRegistry>,
    _requests: usize,
    _depth: usize,
    _pool: &[Tensor<f32>],
) -> Option<PipelineStats> {
    None
}

/// Model names chosen to spread over all 4 shards under the stable
/// FNV-1a routing hash (shards 0, 3, 2, 1 respectively — pinned by a
/// unit test in `coordinator::server`), so the 4-shard run actually
/// exercises the whole pool.
const SHARD_MODELS: [&str; 4] = ["digits-v0", "digits-v1", "digits-v2", "digits-v3"];

/// Shard-scaling phase: the same ≥2-model open-loop load against a
/// 1-shard and a 4-shard pool, back to back.  Backends run with one
/// execution thread per shard so the shard count — not row parallelism —
/// is the axis being measured; the offered rate is set well above the
/// single-shard capacity, so the achieved rate reads as each pool's
/// capacity.
fn run_shard_scaling(runs: &[RunStats], pool: &[Tensor<f32>], load: usize) -> Vec<ShardStats> {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(51);
    let registry = Arc::new(ModelRegistry::new());
    for (i, name) in SHARD_MODELS.iter().enumerate() {
        let params = arch.init(&mut rng);
        registry.insert(*name, EncodedCnn::encode(arch, &params, 4 * (i + 1), QFormat::W32));
    }
    let models: Vec<Option<String>> =
        SHARD_MODELS.iter().map(|m| Some((*m).to_string())).collect();

    let max_load = runs.iter().map(|r| r.load).max().unwrap_or(0);
    let planned_req_s = runs
        .iter()
        .find(|r| r.config == "planned" && r.load == max_load)
        .map(|r| r.req_s)
        .unwrap_or(500.0);
    let rate = (planned_req_s * 3.0).max(200.0);

    let mut stats = Vec::new();
    for shards in [1usize, 4] {
        let entry = registry.get(SHARD_MODELS[0]).expect("registry model");
        let backend = NativeBackend::new((*entry.enc).clone())
            .with_precision(NativePrecision::Fixed(QFormat::IMAGE32))
            .with_threads(1);
        let coord = CoordinatorBuilder::new()
            .backend(backend)
            .registry(Arc::clone(&registry))
            .default_model(SHARD_MODELS[0])
            .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2)))
            .shards(shards)
            .build()
            .expect("sharded coordinator startup");
        assert_eq!(coord.shards(), shards);
        let mut lrng = Rng::new(61);
        let timeout = DEFAULT_REQUEST_TIMEOUT;
        let r = run_open_loop_models(&coord, &models, pool, load, rate, &mut lrng, timeout);
        assert_eq!(r.errors, 0, "shard bench requests failed");
        let pct = |p| r.percentile_us(p).expect("shard bench measured no latencies");
        let per_shard_batches: Vec<u64> =
            coord.shard_metrics().iter().map(|m| m.batches).collect();
        println!(
            "bench coordinator/shards_{shards}/serve_{load}: offered {:.1} req/s, \
             achieved {:.1} req/s, p99 {} us, per-shard batches {:?}",
            r.offered_hz,
            r.achieved_hz,
            pct(99.0),
            per_shard_batches
        );
        stats.push(ShardStats {
            shards,
            models: SHARD_MODELS.len(),
            load,
            offered_hz: r.offered_hz,
            req_s: r.achieved_hz,
            p50_us: pct(50.0),
            p99_us: pct(99.0),
            per_shard_batches,
        });
    }
    stats
}

/// One single-threaded coordinator pinned to an explicit PASM kernel —
/// row parallelism off so the measured axis is the conv kernel itself.
fn build_kernel_coordinator(enc: EncodedCnn, kernel: KernelChoice) -> Coordinator {
    let backend = NativeBackend::new(enc)
        .with_precision(NativePrecision::Fixed(QFormat::IMAGE32))
        .with_kernel(kernel)
        .with_threads(1);
    CoordinatorBuilder::new()
        .backend(backend)
        .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2)))
        .build()
        .expect("kernel bench coordinator startup")
}

/// Kernel-comparison phase: per-tap vs histogram-accumulate plans on the
/// same fixed-point model, swept over codebook size B.  A wider input
/// (24×24) than the default digits model gives the histogram kernel's
/// cache-blocked tiles real rows to stream; one execution thread and
/// best-of-2 alternating keep the comparison about the kernels.  Before
/// any timing, both kernels' *served* logits are bit-compared against
/// the reference `forward_fx` — a throughput table for kernels that
/// disagree would be worse than no table.
fn run_kernel_comparison(load: usize) -> Vec<KernelStats> {
    let arch = DigitsCnn { in_side: 24, conv1_m: 8, conv2_m: 16, kernel: 3, classes: 10 };
    let conv2_taps = arch.conv1_m * arch.kernel * arch.kernel;
    let mut rng = Rng::new(71);
    let params = arch.init(&mut rng);
    let pool: Vec<Tensor<f32>> = (0..64)
        .map(|_| Tensor::from_fn(&[1, arch.in_side, arch.in_side], |_| rng.signed()))
        .collect();
    let mut stats = Vec::new();
    for bins in [4usize, 16, 64] {
        let enc = EncodedCnn::encode(arch, &params, bins, QFormat::W32);
        {
            let per_tap = build_kernel_coordinator(enc.clone(), KernelChoice::PerTap);
            let hist = build_kernel_coordinator(enc.clone(), KernelChoice::Histogram);
            for img in pool.iter().take(4) {
                let want: Vec<u32> = enc
                    .forward_fx(img, ConvVariant::Pasm, QFormat::IMAGE32)
                    .iter()
                    .map(|x| x.to_bits())
                    .collect();
                for (coord, kind) in [(&per_tap, "per-tap"), (&hist, "histogram")] {
                    let resp = coord.infer(img.clone()).expect("kernel bench inference");
                    let got: Vec<u32> = resp.logits.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(got, want, "B={bins}: {kind} kernel diverged from forward_fx");
                }
            }
        }
        let mut best = [0.0f64; 2]; // [per-tap, histogram]
        for _ in 0..2 {
            for (slot, choice) in [(0usize, KernelChoice::PerTap), (1, KernelChoice::Histogram)] {
                let coord = build_kernel_coordinator(enc.clone(), choice);
                let t0 = Instant::now();
                let rxs: Vec<_> = (0..load)
                    .map(|i| coord.submit(pool[i % pool.len()].clone()).unwrap())
                    .collect();
                for rx in rxs {
                    rx.recv().unwrap().expect("kernel bench inference failed");
                }
                let req_s = load as f64 / t0.elapsed().as_secs_f64();
                best[slot] = best[slot].max(req_s);
            }
        }
        println!(
            "bench coordinator/kernels/serve_{load}: B={bins}, conv2 taps {conv2_taps}: \
             per-tap {:.1} req/s, histogram {:.1} req/s ({:.2}x)",
            best[0],
            best[1],
            best[1] / best[0]
        );
        stats.push(KernelStats {
            bins,
            load,
            conv2_taps,
            per_tap_req_s: best[0],
            histogram_req_s: best[1],
        });
    }
    stats
}

/// Elasticity-phase model ids; the first is the hot head of the Zipf
/// law, the rest are the cool multi-tenant tail.
const ELASTIC_MODELS: usize = 8;

/// Hot-model elasticity phase: the same Zipf-skewed open-loop schedule
/// against a 4-shard pool (1 execution thread per shard), with
/// cross-shard batch stealing off and then on.  The offered rate is
/// pegged to a measured single-shard ceiling so the hot model alone
/// overruns its home shard while the pool keeps idle thief capacity —
/// the skew the steal protocol exists to absorb.  Before any timing,
/// hot-model logits served through the **stolen** path (eager donation,
/// `steal_promote_us(0)`) are bit-compared against `forward_fx`.
/// The full run **asserts** the hot model's throughput lifts >= 1.4x
/// with stealing on; `--smoke` only requires steals to have happened.
fn run_elasticity(load: usize, smoke: bool) -> ElasticityStats {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(81);
    let registry = Arc::new(ModelRegistry::new());
    let mut names = Vec::new();
    for i in 0..ELASTIC_MODELS {
        let params = arch.init(&mut rng);
        let name = format!("digits-z{i}");
        registry.insert(&name, EncodedCnn::encode(arch, &params, 8, QFormat::W32));
        names.push(name);
    }
    let hot = names[0].clone();
    let models: Vec<Option<String>> = names.iter().map(|n| Some(n.clone())).collect();
    let pool: Vec<Tensor<f32>> =
        (0..64).map(|i| render_digit(&mut rng, i % 10, 0.05)).collect();

    let build = |shards: usize, steal: bool, promote_us: Option<u64>| {
        let entry = registry.get(&hot).expect("registry model");
        let backend = NativeBackend::new((*entry.enc).clone())
            .with_precision(NativePrecision::Fixed(QFormat::IMAGE32))
            .with_threads(1);
        let mut b = CoordinatorBuilder::new()
            .backend(backend)
            .registry(Arc::clone(&registry))
            .default_model(&hot)
            .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2)))
            .shards(shards)
            .steal(steal);
        if let Some(us) = promote_us {
            b = b.steal_promote_us(us);
        }
        b.build().expect("elasticity coordinator startup")
    };

    // stolen execution must be bit-identical to the reference forward.
    // Eager donation (promote threshold 0) makes thief shards run hot
    // batches; whether a given batch lands on home or a thief is timing,
    // so retry the burst until at least one steal actually happened.
    let want: Vec<Vec<u32>> = {
        let entry = registry.get(&hot).expect("registry model");
        pool.iter()
            .map(|img| {
                entry
                    .enc
                    .forward_fx(img, ConvVariant::Pasm, QFormat::IMAGE32)
                    .iter()
                    .map(|x| x.to_bits())
                    .collect()
            })
            .collect()
    };
    let mut verified_steals = 0u64;
    for _attempt in 0..5 {
        let coord = build(4, true, Some(0));
        let rxs: Vec<_> = (0..64)
            .map(|i| coord.submit_to(&hot, pool[i % pool.len()].clone()).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().expect("elasticity verification inference");
            let got: Vec<u32> = resp.logits.iter().map(|x| x.to_bits()).collect();
            assert_eq!(
                got,
                want[i % pool.len()],
                "stolen-path logits diverged from forward_fx (request {i})"
            );
        }
        verified_steals = coord.metrics().stolen_batches;
        if verified_steals >= 1 {
            break;
        }
    }
    assert!(verified_steals >= 1, "eager-donation verification never produced a steal");
    println!("verified: stolen-path logits bit-identical to forward_fx ({verified_steals} steals)");

    // single-shard ceiling for the hot model, measured closed-loop
    let probe = (load / 2).max(128);
    let single_req_s = {
        let coord = build(1, false, None);
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..probe)
            .map(|i| coord.submit_to(&hot, pool[i % pool.len()].clone()).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().expect("capacity probe inference");
        }
        probe as f64 / t0.elapsed().as_secs_f64()
    };

    // rate x head-share must overrun one shard while total stays under
    // the 4-shard pool: s = 1.6 over 8 ranks puts ~55% on the head, so
    // 3x the single-shard ceiling offers the hot model ~1.65 shards of
    // work with ~1.35 shards of tail spread across the rest
    let zipf_s = 1.6;
    let rate = (single_req_s * 3.0).max(100.0);
    let run_leg = |steal: bool| {
        let coord = build(4, steal, None);
        let mut lrng = Rng::new(91);
        let opts = ZipfOptions { s: zipf_s, burst: None, timeout: DEFAULT_REQUEST_TIMEOUT };
        let r = run_open_loop_zipf(&coord, &models, &pool, load, rate, &mut lrng, opts);
        assert_eq!(r.errors, 0, "elasticity bench requests failed (steal {steal})");
        let m = coord.metrics();
        let per_shard = coord.shard_metrics();
        (r, m, per_shard)
    };
    let (off_r, off_m, _) = run_leg(false);
    let (on_r, on_m, on_shards) = run_leg(true);

    let hot_off = off_r.per_model[&hot].achieved_hz;
    let hot_on = on_r.per_model[&hot].achieved_hz;
    assert_eq!(off_m.stolen_batches, 0, "steal-off leg must never steal");
    assert!(on_m.stolen_batches >= 1, "steal-on leg recorded no stolen batches");
    assert_eq!(
        on_m.stolen_batches, on_m.donated_batches,
        "every stolen batch is donated exactly once in a merged snapshot"
    );
    let stats = ElasticityStats {
        shards: 4,
        models: ELASTIC_MODELS,
        load,
        zipf_s,
        offered_hz: rate,
        hot_off_req_s: hot_off,
        hot_on_req_s: hot_on,
        total_off_req_s: off_r.achieved_hz,
        total_on_req_s: on_r.achieved_hz,
        stolen_batches: on_m.stolen_batches,
        donated_batches: on_m.donated_batches,
        replicas_installed: on_m.replicas_installed,
        per_shard_batches_on: on_shards.iter().map(|m| m.batches).collect(),
        per_shard_stolen_on: on_shards.iter().map(|m| m.stolen_batches).collect(),
    };
    println!(
        "bench coordinator/elasticity/serve_{load}: zipf s={zipf_s} over {} models, \
         offered {rate:.1} req/s; hot '{hot}' steal-off {hot_off:.1} -> steal-on \
         {hot_on:.1} req/s ({:.2}x), {} stolen / {} donated batch(es), {} replica install(s)",
        ELASTIC_MODELS,
        stats.hot_lift(),
        stats.stolen_batches,
        stats.donated_batches,
        stats.replicas_installed
    );
    if !smoke {
        assert!(
            stats.hot_lift() >= 1.4,
            "hot-model ceiling lifted only {:.2}x with stealing on \
             ({hot_off:.1} -> {hot_on:.1} req/s) — the elasticity acceptance bar is 1.4x",
            stats.hot_lift()
        );
    }
    stats
}

/// Loud-failure gate: every section this run claims to have measured
/// must hold real numbers.  A placeholder (empty section, zero req/s)
/// panics — `BENCH_serving.json` is only ever rewritten with data.
#[allow(clippy::too_many_arguments)]
fn ensure_measured(
    runs: &[RunStats],
    net: &[NetStats],
    shards: &[ShardStats],
    pipeline: Option<&PipelineStats>,
    stages: &[StageStat],
    trace_overhead: &TraceOverheadStats,
    kernels: &[KernelStats],
    elasticity: &ElasticityStats,
) {
    assert!(
        elasticity.hot_off_req_s > 0.0 && elasticity.hot_on_req_s > 0.0,
        "placeholder req_s in the elasticity comparison"
    );
    assert!(
        elasticity.stolen_batches >= 1,
        "refusing to write a placeholder: the elasticity phase recorded no steals"
    );
    assert!(!runs.is_empty(), "refusing to write a placeholder: no in-process runs measured");
    assert!(!net.is_empty(), "refusing to write a placeholder: no socket loads measured");
    assert!(!shards.is_empty(), "refusing to write a placeholder: no shard runs measured");
    assert!(!kernels.is_empty(), "refusing to write a placeholder: no kernel runs measured");
    for k in kernels {
        assert!(
            k.per_tap_req_s > 0.0 && k.histogram_req_s > 0.0,
            "placeholder req_s in the kernel comparison at B={}",
            k.bins
        );
    }
    assert!(
        stages.iter().filter(|s| s.count > 0).count() == 4,
        "refusing to write a placeholder: the socket phase left a stage histogram empty"
    );
    assert!(
        trace_overhead.off_req_s > 0.0 && trace_overhead.on_req_s > 0.0,
        "placeholder req_s in the trace-overhead comparison"
    );
    for r in runs {
        assert!(r.req_s > 0.0, "placeholder req_s in run '{}' at load {}", r.config, r.load);
    }
    for r in net {
        assert!(r.req_s > 0.0, "placeholder req_s in net/{} at load {}", r.server, r.load);
    }
    for r in shards {
        assert!(r.req_s > 0.0, "placeholder req_s in shards={} run", r.shards);
    }
    if cfg!(unix) {
        assert!(
            net.iter().any(|r| r.server == "evented"),
            "refusing to write a placeholder: the evented front-end was not measured"
        );
        let p = pipeline.expect("refusing to write a placeholder: pipelining was not measured");
        assert!(
            p.serial_req_s > 0.0 && p.pipelined_req_s > 0.0,
            "placeholder req_s in the pipeline comparison"
        );
    }
}

// one parameter per measured section; a bundling struct would only move
// the field list somewhere else
#[allow(clippy::too_many_arguments)]
fn write_json(
    runs: &[RunStats],
    net: &[NetStats],
    shards: &[ShardStats],
    pipeline: Option<&PipelineStats>,
    artifact: &ArtifactStats,
    stages: &[StageStat],
    trace_overhead: &TraceOverheadStats,
    kernels: &[KernelStats],
    elasticity: &ElasticityStats,
) {
    ensure_measured(runs, net, shards, pipeline, stages, trace_overhead, kernels, elasticity);
    let max_load = runs.iter().map(|r| r.load).max().unwrap_or(0);
    let base = runs.iter().find(|r| r.config == "baseline" && r.load == max_load);
    let plan = runs.iter().find(|r| r.config == "planned" && r.load == max_load);

    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"coordinator_serving\",\n");
    s.push_str(
        "  \"model\": \"digits_cnn bins=16 wq=W32 fixed-point IMAGE32, \
         served from a .pasm registry\",\n",
    );
    s.push_str("  \"baseline_label\": \"pre-plan per-request encode, serial rows\",\n");
    s.push_str(
        "  \"planned_label\": \"compiled layer plans + parallel batch rows, \
         registry-loaded model\",\n",
    );
    let _ = writeln!(
        s,
        "  \"artifact\": {{\"file_bytes\": {}, \"raw_f32_bytes\": {}, \
         \"compression_ratio\": {:.2}}},",
        artifact.file_bytes,
        artifact.raw_f32_bytes,
        artifact.ratio()
    );
    s.push_str("  \"runs\": [\n");
    for (i, r) in runs.iter().enumerate() {
        let sep = if i + 1 == runs.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"config\": \"{}\", \"backend\": \"{}\", \"load\": {}, \
             \"req_s\": {:.1}, \"p50_us\": {}, \"p90_us\": {}, \"p99_us\": {}, \
             \"mean_occupancy\": {:.2}, \"padding_fraction\": {:.3}, \"batches\": {}}}{sep}",
            r.config,
            r.backend,
            r.load,
            r.req_s,
            r.p50_us,
            r.p90_us,
            r.p99_us,
            r.mean_occupancy,
            r.padding_fraction,
            r.batches
        );
    }
    s.push_str("  ],\n");
    s.push_str(
        "  \"net_label\": \"open-loop Poisson over TCP sockets (wire protocol), \
         registry-loaded model; 'server' is the front-end kind\",\n",
    );
    s.push_str("  \"net\": [\n");
    for (i, r) in net.iter().enumerate() {
        let sep = if i + 1 == net.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"server\": \"{}\", \"load\": {}, \"offered_hz\": {:.1}, \"req_s\": {:.1}, \
             \"p50_us\": {}, \"p90_us\": {}, \"p99_us\": {}, \
             \"overloaded\": {}, \"errors\": {}}}{sep}",
            r.server,
            r.load,
            r.offered_hz,
            r.req_s,
            r.p50_us,
            r.p90_us,
            r.p99_us,
            r.overloaded,
            r.errors
        );
    }
    s.push_str("  ],\n");
    let max_net = net.iter().map(|r| r.load).max().unwrap_or(0);
    let threaded = net.iter().find(|r| r.server == "threaded" && r.load == max_net);
    let evented = net.iter().find(|r| r.server == "evented" && r.load == max_net);
    match (threaded, evented) {
        (Some(t), Some(e)) => {
            let _ = writeln!(
                s,
                "  \"frontend_comparison\": {{\"load\": {}, \"threaded_req_s\": {:.1}, \
                 \"evented_req_s\": {:.1}, \"ratio\": {:.2}}},",
                max_net,
                t.req_s,
                e.req_s,
                e.req_s / t.req_s
            );
        }
        _ => s.push_str("  \"frontend_comparison\": null,\n"),
    }
    s.push_str(
        "  \"pipeline_label\": \"one connection, closed loop against the evented server: \
         serial window of 1 vs negotiated pipelined window\",\n",
    );
    match pipeline {
        Some(p) => {
            let _ = writeln!(
                s,
                "  \"pipeline\": {{\"requests\": {}, \"depth\": {}, \"window\": {}, \
                 \"serial_req_s\": {:.1}, \"pipelined_req_s\": {:.1}, \"speedup\": {:.2}}},",
                p.requests,
                p.depth,
                p.window,
                p.serial_req_s,
                p.pipelined_req_s,
                p.pipelined_req_s / p.serial_req_s
            );
        }
        None => s.push_str("  \"pipeline\": null,\n"),
    }
    s.push_str(
        "  \"shards_label\": \"1-shard vs 4-shard coordinator pool, 4 models, \
         open-loop over-capacity load, 1 execution thread per shard\",\n",
    );
    s.push_str("  \"shards\": [\n");
    for (i, r) in shards.iter().enumerate() {
        let sep = if i + 1 == shards.len() { "" } else { "," };
        let batches: Vec<String> = r.per_shard_batches.iter().map(u64::to_string).collect();
        let _ = writeln!(
            s,
            "    {{\"shards\": {}, \"models\": {}, \"load\": {}, \"offered_hz\": {:.1}, \
             \"req_s\": {:.1}, \"p50_us\": {}, \"p99_us\": {}, \
             \"per_shard_batches\": [{}]}}{sep}",
            r.shards,
            r.models,
            r.load,
            r.offered_hz,
            r.req_s,
            r.p50_us,
            r.p99_us,
            batches.join(", ")
        );
    }
    s.push_str("  ],\n");
    let one = shards.iter().find(|r| r.shards == 1);
    let four = shards.iter().find(|r| r.shards == 4);
    match (one, four) {
        (Some(o), Some(f)) => {
            let _ = writeln!(
                s,
                "  \"shard_comparison\": {{\"load\": {}, \"shards_1_req_s\": {:.1}, \
                 \"shards_4_req_s\": {:.1}, \"speedup\": {:.2}}},",
                o.load,
                o.req_s,
                f.req_s,
                f.req_s / o.req_s
            );
        }
        _ => s.push_str("  \"shard_comparison\": null,\n"),
    }
    s.push_str(
        "  \"stages_label\": \"per-stage latency histograms merged across shards, \
         harvested from the threaded socket phase (write_back only has samples \
         behind a front-end)\",\n",
    );
    s.push_str("  \"stages\": [\n");
    for (i, st) in stages.iter().enumerate() {
        let sep = if i + 1 == stages.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"stage\": \"{}\", \"count\": {}, \"p50_us\": {}, \"p99_us\": {}, \
             \"mean_us\": {:.1}}}{sep}",
            st.stage, st.count, st.p50_us, st.p99_us, st.mean_us
        );
    }
    s.push_str("  ],\n");
    s.push_str(
        "  \"trace_overhead_label\": \"planned path, lifecycle tracing off \
         (trace_capacity 0) vs on (default ring), best of 3 alternating; \
         the bench asserts ratio >= 0.98\",\n",
    );
    let _ = writeln!(
        s,
        "  \"trace_overhead\": {{\"load\": {}, \"off_req_s\": {:.1}, \"on_req_s\": {:.1}, \
         \"ratio\": {:.3}}},",
        trace_overhead.load,
        trace_overhead.off_req_s,
        trace_overhead.on_req_s,
        trace_overhead.ratio()
    );
    s.push_str(
        "  \"kernels_label\": \"per-tap vs histogram-accumulate PASM plans, fixed-point \
         IMAGE32/W32, 24x24 input, 1 execution thread, best of 2 alternating, served \
         logits bit-checked against forward_fx before timing\",\n",
    );
    s.push_str("  \"kernels\": [\n");
    for (i, k) in kernels.iter().enumerate() {
        let sep = if i + 1 == kernels.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"bins\": {}, \"load\": {}, \"conv2_taps\": {}, \
             \"per_tap_req_s\": {:.1}, \"histogram_req_s\": {:.1}, \"ratio\": {:.2}}}{sep}",
            k.bins,
            k.load,
            k.conv2_taps,
            k.per_tap_req_s,
            k.histogram_req_s,
            k.histogram_req_s / k.per_tap_req_s
        );
    }
    s.push_str("  ],\n");
    s.push_str(
        "  \"elasticity_label\": \"Zipf-skewed multi-tenant open loop at 4 shards \
         (1 execution thread each), cross-shard batch stealing off vs on; hot model = \
         head of the Zipf law; stolen-path logits bit-checked against forward_fx; the \
         full run asserts hot_lift >= 1.4\",\n",
    );
    let pb: Vec<String> = elasticity.per_shard_batches_on.iter().map(u64::to_string).collect();
    let ps: Vec<String> = elasticity.per_shard_stolen_on.iter().map(u64::to_string).collect();
    let _ = writeln!(
        s,
        "  \"elasticity\": {{\"shards\": {}, \"models\": {}, \"load\": {}, \"zipf_s\": {:.2}, \
         \"offered_hz\": {:.1}, \"steal_off_hot_req_s\": {:.1}, \"steal_on_hot_req_s\": {:.1}, \
         \"hot_lift\": {:.2}, \"steal_off_req_s\": {:.1}, \"steal_on_req_s\": {:.1}, \
         \"stolen_batches\": {}, \"donated_batches\": {}, \"replicas_installed\": {}, \
         \"per_shard_batches\": [{}], \"per_shard_stolen\": [{}]}},",
        elasticity.shards,
        elasticity.models,
        elasticity.load,
        elasticity.zipf_s,
        elasticity.offered_hz,
        elasticity.hot_off_req_s,
        elasticity.hot_on_req_s,
        elasticity.hot_lift(),
        elasticity.total_off_req_s,
        elasticity.total_on_req_s,
        elasticity.stolen_batches,
        elasticity.donated_batches,
        elasticity.replicas_installed,
        pb.join(", "),
        ps.join(", ")
    );
    match (base, plan) {
        (Some(b), Some(p)) => {
            let _ = writeln!(
                s,
                "  \"comparison\": {{\"load\": {}, \"baseline_req_s\": {:.1}, \
                 \"planned_req_s\": {:.1}, \"speedup\": {:.2}}}",
                max_load,
                b.req_s,
                p.req_s,
                p.req_s / b.req_s
            );
        }
        _ => s.push_str("  \"comparison\": null\n"),
    }
    s.push_str("}\n");
    std::fs::write(JSON_PATH, &s).expect("write BENCH_serving.json");
    println!("wrote {JSON_PATH}");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let loads: &[usize] = if smoke { &[64] } else { &[64, 256, 1024] };

    let arch = DigitsCnn::default();
    let mut rng = Rng::new(3);
    let params = arch.init(&mut rng);
    let enc = EncodedCnn::encode(arch, &params, 16, QFormat::W32);

    // pack -> registry: the artifact chain the planned path serves from
    let (registry, artifact, models_dir) = pack_into_registry(&enc);
    println!(
        "artifact: {} bytes on disk vs {} bytes raw f32 -> {:.1}x compression",
        artifact.file_bytes,
        artifact.raw_f32_bytes,
        artifact.ratio()
    );

    // pre-render a request pool
    let pool: Vec<_> = (0..256)
        .map(|i| render_digit(&mut rng, i % 10, 0.05))
        .collect();

    verify_bitexact(&enc, &registry, &pool);
    let loaded = (*registry.get(MODEL).expect("registry model").enc).clone();

    let mut runs = Vec::new();
    for &load in loads {
        let baseline = build(loaded.clone(), false, None);
        runs.push(run_load("baseline", &baseline, load, &pool));
        drop(baseline);
        let planned = build(loaded.clone(), true, Some(&registry));
        runs.push(run_load("planned", &planned, load, &pool));
    }

    // socket path: same model, same loads, through both TCP front-ends;
    // the threaded phase also yields the per-stage histogram summary
    let (mut net, stages) = run_net_loads("threaded", &loaded, &registry, &runs, loads, &pool);
    let (evented_net, _) = run_net_loads("evented", &loaded, &registry, &runs, loads, &pool);
    net.extend(evented_net);

    // protocol pipelining: serial vs windowed on one evented connection
    let pipe_requests = if smoke { 256 } else { 1024 };
    let pipeline = run_pipeline_comparison(&loaded, &registry, pipe_requests, 32, &pool);

    // shard scaling: ≥2 models under open-loop load, 1 vs 4 shards
    let shard_load = if smoke { 256 } else { 2048 };
    let shards = run_shard_scaling(&runs, &pool, shard_load);

    // observability pricing: tracing off vs on, gated at <= 2% overhead
    let overhead_load = if smoke { 512 } else { 2048 };
    let trace_overhead = run_trace_overhead(&loaded, &registry, overhead_load, &pool);

    // PASM kernel comparison: per-tap vs histogram-accumulate over B
    let kernel_load = if smoke { 256 } else { 1024 };
    let kernels = run_kernel_comparison(kernel_load);

    // hot-model elasticity: Zipf skew at 4 shards, steal off vs on
    let elastic_load = if smoke { 256 } else { 2048 };
    let elasticity = run_elasticity(elastic_load, smoke);

    let max_load = loads.last().copied().unwrap();
    let base = runs.iter().find(|r| r.config == "baseline" && r.load == max_load).unwrap();
    let plan = runs.iter().find(|r| r.config == "planned" && r.load == max_load).unwrap();
    println!(
        "speedup at load {max_load}: {:.2}x ({:.1} -> {:.1} req/s)",
        plan.req_s / base.req_s,
        base.req_s,
        plan.req_s
    );
    if let (Some(one), Some(four)) = (
        shards.iter().find(|r| r.shards == 1),
        shards.iter().find(|r| r.shards == 4),
    ) {
        println!(
            "shard speedup at load {}: {:.2}x ({:.1} -> {:.1} req/s)",
            one.load,
            four.req_s / one.req_s,
            one.req_s,
            four.req_s
        );
    }

    write_json(
        &runs,
        &net,
        &shards,
        pipeline.as_ref(),
        &artifact,
        &stages,
        &trace_overhead,
        &kernels,
        &elasticity,
    );
    let _ = std::fs::remove_dir_all(&models_dir);
}
