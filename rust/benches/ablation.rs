//! `cargo bench --bench ablation` — ablation of the design choices
//! DESIGN.md calls out, on the paper tile:
//!
//! 1. HLS `ARRAY_PARTITION` of imageBin: partitioned gather trees vs the
//!    §5.3 banked fallback (area/power vs latency).
//! 2. `ALLOCATION` post-pass multiplier budget 1/2/4/8 (latency vs area).
//! 3. Clock target 100 MHz / 800 MHz / 1 GHz (timing pressure on the
//!    16-bin PASM — the Fig 17 mechanism isolated).
//! 4. Weight width 8/16/32 at fixed B (the Fig 18 axis, denser).

use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind};
use pasm_accel::accel::hls::HlsConfig;
use pasm_accel::hw::Tech;

fn main() {
    let t1g = Tech::asic_1ghz();

    println!("--- ablation 1: ARRAY_PARTITION of imageBin (B=16, W=32, 1 GHz) ---");
    for (name, partition) in [("partitioned (paper)", true), ("banked (§5.3 fallback)", false)] {
        let mut a = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32);
        a.hls.partition_bins = partition;
        println!(
            "{name:<24} gates {:>10.0}  power {:>8.2} mW  latency {:>6} cycles",
            a.gates(&t1g).total(),
            a.power(&t1g).total_w() * 1e3,
            a.latency_cycles()
        );
    }

    println!("\n--- ablation 2: post-pass ALLOCATION limit (B=16, W=32) ---");
    for muls in [1usize, 2, 4, 8] {
        let mut a = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32);
        a.hls = HlsConfig::default().with_postpass_muls(muls);
        println!(
            "muls={muls}: gates {:>10.0}  power {:>8.2} mW  latency {:>6.1} cycles",
            a.gates(&t1g).total(),
            a.power(&t1g).total_w() * 1e3,
            a.latency_cycles_exact()
        );
    }

    println!("\n--- ablation 3: clock target (B=16, W=32, PASM vs WS) ---");
    for (name, tech) in [
        ("100MHz", Tech::asic_100mhz()),
        ("800MHz", Tech::asic_800mhz()),
        ("1GHz", Tech::asic_1ghz()),
    ] {
        let ws = ConvAccel::paper(ConvVariantKind::WeightShared, 16, 32);
        let pasm = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32);
        let (gw, gp) = (ws.gates(&tech).total(), pasm.gates(&tech).total());
        println!(
            "{name:<8} WS {gw:>10.0}  PASM {gp:>10.0}  delta {:+6.1}%  (u_pasm {:.2})",
            (gp / gw - 1.0) * 100.0,
            pasm.path_utilization(&tech)
        );
    }

    println!("\n--- ablation 4: weight width at B=4 ---");
    for ww in [8u32, 16, 32] {
        let ws = ConvAccel::paper(ConvVariantKind::WeightShared, 4, ww);
        let pasm = ConvAccel::paper(ConvVariantKind::Pasm, 4, ww);
        let (gw, gp) = (ws.gates(&t1g).total(), pasm.gates(&t1g).total());
        let (pw, pp) = (ws.power(&t1g).total_w(), pasm.power(&t1g).total_w());
        println!(
            "W={ww:<3} gates {:+6.1}%  power {:+6.1}%",
            (gp / gw - 1.0) * 100.0,
            (pp / pw - 1.0) * 100.0
        );
    }
}
