//! `cargo bench --bench sim_hotpath` — throughput of the cycle-accurate
//! simulator's hot loops (the §Perf optimization target: DESIGN.md aims at
//! >= 1e8 unit-cycles/s so full figure sweeps run in seconds).
//!
//! Benches:
//! * standalone streaming: 16-MAC and 16-PAS-4-MAC over 4096-pair streams
//!   (unit-cycles/s = lanes x pairs / wall time)
//! * conv tile simulation: WS and PASM variants on the paper tile
//! * functional fixed-point dataflows (the pure compute without the
//!   simulator's probes) for comparison — the probe overhead is visible as
//!   the gap between the two.

use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind};
use pasm_accel::accel::standalone::StandaloneUnit;
use pasm_accel::cnn::conv::{pasm_conv_fx, ws_conv_fx, FxConvInputs};
use pasm_accel::cnn::data::Rng;
use pasm_accel::quant::codebook::encode_weights;
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::report::bench::{bench, black_box};
use pasm_accel::sim::conv::simulate_conv;
use pasm_accel::sim::standalone::{random_streams, simulate_standalone};
use pasm_accel::tensor::Tensor;
use std::time::Duration;

fn main() {
    let mut rng = Rng::new(1);
    const PAIRS: usize = 4096;
    let streams = random_streams(&mut rng, 16, PAIRS, 16, 1 << 20);
    let cb: Vec<i64> = (0..16).map(|_| (rng.signed() * 1e5) as i64).collect();

    let mac16 = StandaloneUnit::mac16(32, 16);
    let r = bench("sim/standalone_mac16_4096", Duration::from_secs(1), 16, || {
        black_box(simulate_standalone(&mac16, &streams, &cb));
    });
    r.print();
    println!(
        "  => {:.2e} unit-cycles/s",
        (16 * PAIRS) as f64 * r.per_second()
    );

    let pasm16 = StandaloneUnit::pas16mac4(32, 16);
    let r = bench("sim/standalone_pasm_4096", Duration::from_secs(1), 16, || {
        black_box(simulate_standalone(&pasm16, &streams, &cb));
    });
    r.print();
    println!(
        "  => {:.2e} unit-cycles/s",
        (16 * PAIRS + 4 * 16) as f64 * r.per_second()
    );

    // conv tile inputs
    let image = Tensor::from_fn(&[15, 5, 5], |_| rng.signed() * 4.0);
    let w = Tensor::from_fn(&[2, 15, 3, 3], |_| rng.signed());
    let enc = encode_weights(&w, 16, QFormat::W16);
    let inputs = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 1);

    let ws_accel = ConvAccel::paper(ConvVariantKind::WeightShared, 16, 32);
    let r = bench("sim/conv_ws_tile", Duration::from_secs(1), 32, || {
        black_box(simulate_conv(&ws_accel, &inputs));
    });
    r.print();

    let pasm_accel = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32);
    let r = bench("sim/conv_pasm_tile", Duration::from_secs(1), 32, || {
        black_box(simulate_conv(&pasm_accel, &inputs));
    });
    r.print();

    // functional dataflows (no probes) for overhead comparison
    let r = bench("fx/ws_conv_tile", Duration::from_secs(1), 32, || {
        black_box(ws_conv_fx(&inputs));
    });
    r.print();
    let r = bench("fx/pasm_conv_tile", Duration::from_secs(1), 32, || {
        black_box(pasm_conv_fx(&inputs));
    });
    r.print();
}
