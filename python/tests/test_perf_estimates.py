"""L1 performance-estimate sanity (DESIGN.md §8: real-TPU perf is estimated
from VMEM footprint + MXU utilization, since interpret=True gives only
CPU-numpy timings)."""

import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import PAPER_TILE
from compile.kernels import pasm_conv as pk


def test_paper_tile_fits_vmem():
    """The paper tile's working set must fit comfortably in ~16 MiB VMEM."""
    t = PAPER_TILE
    ckk = t.channels * t.kernel_h * t.kernel_w
    bytes_ = pk.vmem_footprint_bytes(ckk, t.bins)
    assert bytes_ < 1 << 20, f"{bytes_} bytes"  # < 1 MiB


def test_footprint_monotonic():
    assert pk.vmem_footprint_bytes(128, 16) < pk.vmem_footprint_bytes(256, 16)
    assert pk.vmem_footprint_bytes(128, 16) < pk.vmem_footprint_bytes(128, 64)
    assert pk.vmem_footprint_bytes(128, 16, tile_t=64) < pk.vmem_footprint_bytes(
        128, 16, tile_t=256
    )


def test_mxu_utilization_bounds_and_saturation():
    # B < 128 under-fills the lane axis; B >= 128 saturates
    u16 = pk.mxu_utilization_estimate(135, 16)
    u128 = pk.mxu_utilization_estimate(135, 128)
    u256 = pk.mxu_utilization_estimate(135, 256)
    assert 0.0 < u16 < u128 <= 1.0
    assert u128 == u256  # saturated at the 128-lane MXU edge


@settings(max_examples=30, deadline=None)
@given(
    ckk=st.integers(1, 4096),
    bins=st.integers(1, 512),
    tile_log2=st.integers(3, 9),
)
def test_estimates_always_valid(ckk, bins, tile_log2):
    tile = 1 << tile_log2
    bytes_ = pk.vmem_footprint_bytes(ckk, bins, tile_t=tile)
    assert bytes_ > 0
    u = pk.mxu_utilization_estimate(ckk, bins, tile_t=tile)
    assert 0.0 < u <= 1.0


def test_default_tile_is_mxu_aligned():
    assert pk.DEFAULT_TILE_T % 8 == 0
    assert pk.mxu_utilization_estimate(135, 16, tile_t=pk.DEFAULT_TILE_T) == pytest.approx(
        (16 / 128) * 1.0
    )
