"""L2 model forward: shapes, PASM-vs-WS variant agreement, param specs."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.configs import E2E_MODEL

jax.config.update("jax_platform_name", "cpu")

CFG = E2E_MODEL


def _params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_config_arithmetic():
    assert CFG.conv1.out_h == 10
    assert CFG.pool1_hw == 5
    assert CFG.conv2.out_h == 3
    assert CFG.feature_dim == CFG.conv2_m * 9


def test_forward_shapes():
    params = _params()
    rng = np.random.default_rng(0)
    images = jnp.asarray(rng.standard_normal((2, CFG.in_c, CFG.in_h, CFG.in_w)), jnp.float32)
    logits = M.model_forward(CFG, images, params, variant="pasm")
    assert logits.shape == (2, CFG.classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_pasm_ws_variants_agree():
    """The exported PASM model must match a WS-MAC model (paper §5.3)."""
    params = _params()
    rng = np.random.default_rng(1)
    images = jnp.asarray(rng.standard_normal((2, CFG.in_c, CFG.in_h, CFG.in_w)), jnp.float32)
    a = M.model_forward(CFG, images, params, variant="pasm")
    b = M.model_forward(CFG, images, params, variant="ws")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_param_specs_match_init():
    params = _params()
    specs = M.model_param_specs(CFG)
    assert set(specs) == set(params) == set(M.PARAM_ORDER)
    for k, spec in specs.items():
        assert tuple(params[k].shape) == tuple(spec.shape), k
        assert params[k].dtype == spec.dtype, k


def test_flat_forward_matches_dict():
    params = _params()
    rng = np.random.default_rng(2)
    images = jnp.asarray(rng.standard_normal((1, CFG.in_c, CFG.in_h, CFG.in_w)), jnp.float32)
    fn = M.model_forward_flat(CFG)
    flat = [params[k] for k in M.PARAM_ORDER]
    np.testing.assert_allclose(
        np.asarray(fn(images, *flat)),
        np.asarray(M.model_forward(CFG, images, params)),
        rtol=1e-6,
    )


def test_batch_independence():
    """Each batch row is computed independently (no cross-talk)."""
    params = _params()
    rng = np.random.default_rng(3)
    images = jnp.asarray(rng.standard_normal((4, CFG.in_c, CFG.in_h, CFG.in_w)), jnp.float32)
    full = M.model_forward(CFG, images, params)
    for i in range(4):
        one = M.model_forward(CFG, images[i : i + 1], params)
        np.testing.assert_allclose(np.asarray(one[0]), np.asarray(full[i]), rtol=1e-5, atol=1e-5)
