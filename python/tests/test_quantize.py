"""K-means weight-sharing quantizer tests (python side)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quantize

jax.config.update("jax_platform_name", "cpu")


def test_exact_clusters_recovered():
    """B well-separated point masses -> centroids == the masses."""
    rng = np.random.default_rng(0)
    centers = np.array([-3.0, -1.0, 1.0, 3.0], np.float32)
    x = np.repeat(centers, 50) + rng.normal(0, 1e-3, 200).astype(np.float32)
    cb, assign = quantize.kmeans_1d(jnp.asarray(x), 4)
    np.testing.assert_allclose(np.sort(np.asarray(cb)), centers, atol=1e-2)
    # every point assigned to its nearest centroid
    d = np.abs(x[:, None] - np.asarray(cb)[None, :])
    np.testing.assert_array_equal(np.asarray(assign), d.argmin(1))


def test_assignment_range_and_shape():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((4, 3, 3, 3)), jnp.float32)
    cb, bi = quantize.quantize_weights(w, 16)
    assert cb.shape == (16,)
    assert bi.shape == w.shape
    assert int(bi.min()) >= 0 and int(bi.max()) < 16


def test_mse_decreases_with_bins():
    """More bins -> no worse reconstruction (paper's B sweep rationale)."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((8, 4, 3, 3)), jnp.float32)
    errs = [float(quantize.quantization_mse(w, b)) for b in (2, 4, 16, 64)]
    for lo, hi in zip(errs[1:], errs[:-1]):
        assert lo <= hi * 1.05  # tolerate tiny Lloyd's nonmonotonicity


def test_single_bin_is_mean():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(100), jnp.float32)
    cb, assign = quantize.kmeans_1d(x, 1)
    np.testing.assert_allclose(float(cb[0]), float(x.mean()), rtol=1e-5)
    assert int(assign.max()) == 0


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 300),
    bins_log2=st.integers(0, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmeans_invariants(n, bins_log2, seed):
    """Codebook finite, assignments in range, decode error <= data range."""
    bins = 2**bins_log2
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * 2.0, jnp.float32)
    cb, assign = quantize.kmeans_1d(x, bins)
    assert cb.shape == (bins,)
    assert np.isfinite(np.asarray(cb)).all()
    a = np.asarray(assign)
    assert a.min() >= 0 and a.max() < bins
    err = np.abs(np.asarray(cb)[a] - np.asarray(x))
    span = float(x.max() - x.min()) + 1e-6
    assert err.max() <= span
