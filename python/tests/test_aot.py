"""AOT lowering sanity: HLO text well-formed, signatures match the manifest.

These run the same lowering path as ``make artifacts`` but keep everything
in-memory (no artifact writes), so pytest stays side-effect free.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model as M
from compile.configs import PAPER_TILE, E2E_MODEL

jax.config.update("jax_platform_name", "cpu")


def test_tile_hlo_text_wellformed():
    lowered = aot.lower_tiles(PAPER_TILE)
    assert set(lowered) == {"pasm_tile", "ws_tile", "direct_tile"}
    for name, low in lowered.items():
        text = aot.to_hlo_text(low)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # 64-bit-id safety: the text parser reassigns ids, but the text we
        # hand it must not carry any id annotations that overflow i32.
        for tok in re.findall(r"id=(\d+)", text):
            assert int(tok) <= 2**31 - 1


def test_tile_signature():
    """pasm_tile: (image f32[C,IH,IW], bi s32[M,C,KY,KX], cb f32[B]) -> f32[M,OH,OW]."""
    t = PAPER_TILE
    text = aot.to_hlo_text(aot.lower_tiles(t)["pasm_tile"])
    # parameter/output declarations live in the ENTRY body, one per line
    params = [l for l in text.splitlines() if "parameter(" in l]
    assert any(f"f32[{t.channels},{t.in_h},{t.in_w}]" in l for l in params)
    assert any(
        f"s32[{t.kernels},{t.channels},{t.kernel_h},{t.kernel_w}]" in l for l in params
    )
    assert any(f"f32[{t.bins}]" in l for l in params)
    assert f"f32[{t.kernels},{t.out_h},{t.out_w}]" in text  # output shape


def test_model_lowering_batch_shapes():
    cfg = E2E_MODEL
    lowered = aot.lower_models(cfg)
    assert set(lowered) == {f"model_b{n}" for n in cfg.batch_sizes}
    for n in cfg.batch_sizes:
        text = aot.to_hlo_text(lowered[f"model_b{n}"])
        params = [l for l in text.splitlines() if "parameter(" in l]
        assert any(f"f32[{n},{cfg.in_c},{cfg.in_h},{cfg.in_w}]" in l for l in params)
        assert f"f32[{n},{cfg.classes}]" in text  # logits shape


def test_manifest_consistent_with_specs():
    manifest = aot.build_manifest(PAPER_TILE, E2E_MODEL)
    specs = M.model_param_specs(E2E_MODEL)
    assert manifest["model_param_order"] == M.PARAM_ORDER
    for k, v in manifest["model_params"].items():
        assert tuple(v["shape"]) == tuple(specs[k].shape)
    assert manifest["tile"]["taps"] == PAPER_TILE.taps


def test_lowered_tile_executes_like_kernel():
    """Compile the lowered pasm_tile with jax and compare to direct call —
    proves the AOT graph is the same computation rust will run."""
    t = PAPER_TILE
    rng = np.random.default_rng(0)
    image = jnp.asarray(rng.standard_normal((t.channels, t.in_h, t.in_w)), jnp.float32)
    bi = jnp.asarray(rng.integers(0, t.bins, (t.kernels, t.channels, t.kernel_h, t.kernel_w)), jnp.int32)
    cb = jnp.asarray(rng.standard_normal(t.bins), jnp.float32)
    compiled = jax.jit(M.tile_forward_pasm).lower(image, bi, cb).compile()
    np.testing.assert_allclose(
        np.asarray(compiled(image, bi, cb)),
        np.asarray(M.tile_forward_pasm(image, bi, cb)),
        rtol=1e-5,
        atol=1e-5,
    )
