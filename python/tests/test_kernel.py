"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Covers the paper's §5.3 exactness claim at the float level:
PASM conv == weight-shared conv == direct conv (decoded weights), plus the
phase-1 (PAS) histogram in isolation against an independent segment_sum
oracle.  Hypothesis sweeps shapes, strides, bins and value ranges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import PAPER_TILE
from compile.kernels import pasm_conv as pk
from compile.kernels import ws_conv as wk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_case(rng, c, ih, iw, ky, kx, m, bins, scale=1.0):
    image = jnp.asarray(rng.standard_normal((c, ih, iw)) * scale, jnp.float32)
    bi = jnp.asarray(rng.integers(0, bins, (m, c, ky, kx)), jnp.int32)
    cb = jnp.asarray(rng.standard_normal(bins), jnp.float32)
    return image, bi, cb


PAPER_CASE = (
    PAPER_TILE.channels,
    PAPER_TILE.in_h,
    PAPER_TILE.in_w,
    PAPER_TILE.kernel_h,
    PAPER_TILE.kernel_w,
    PAPER_TILE.kernels,
    PAPER_TILE.bins,
)


class TestOracles:
    """The oracles must agree among themselves before testing kernels."""

    def test_ws_equals_direct_decoded(self):
        rng = np.random.default_rng(0)
        image, bi, cb = make_case(rng, *PAPER_CASE)
        w = ref.decode_weights(bi, cb)
        np.testing.assert_allclose(
            ref.ws_conv(image, bi, cb), ref.direct_conv(image, w), rtol=1e-5
        )

    def test_pasm_equals_ws(self):
        rng = np.random.default_rng(1)
        image, bi, cb = make_case(rng, *PAPER_CASE)
        np.testing.assert_allclose(
            ref.pasm_conv(image, bi, cb),
            ref.ws_conv(image, bi, cb),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_histogram_matches_onehot(self):
        rng = np.random.default_rng(2)
        image, bi, cb = make_case(rng, *PAPER_CASE)
        hist = ref.pasm_histogram(image, bi[0], PAPER_TILE.bins)
        patches = ref.im2col(image, 3, 3)
        onehot = ref.one_hot_taps(bi, PAPER_TILE.bins)[0]
        np.testing.assert_allclose(hist, patches @ onehot, rtol=1e-5, atol=1e-5)

    def test_im2col_tap_order(self):
        """Column c*KY*KX + ky*KX + kx must hold image[c, y+ky, x+kx]."""
        c, ih, iw, ky, kx = 2, 4, 4, 2, 2
        image = jnp.arange(c * ih * iw, dtype=jnp.float32).reshape(c, ih, iw)
        patches = ref.im2col(image, ky, kx)
        oh = ow = 3
        for t in range(oh * ow):
            y0, x0 = divmod(t, ow)
            for ci in range(c):
                for yy in range(ky):
                    for xx in range(kx):
                        col = ci * ky * kx + yy * kx + xx
                        assert patches[t, col] == image[ci, y0 + yy, x0 + xx]


class TestPasmKernel:
    def test_paper_tile(self):
        rng = np.random.default_rng(3)
        image, bi, cb = make_case(rng, *PAPER_CASE)
        got = pk.pasm_conv(image, bi, cb)
        want = ref.pasm_conv(image, bi, cb)
        assert got.shape == (PAPER_TILE.kernels, PAPER_TILE.out_h, PAPER_TILE.out_w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_against_direct(self):
        rng = np.random.default_rng(4)
        image, bi, cb = make_case(rng, *PAPER_CASE)
        got = pk.pasm_conv(image, bi, cb)
        want = ref.direct_conv(image, ref.decode_weights(bi, cb))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("stride", [1, 2])
    @pytest.mark.parametrize("bins", [4, 8, 16, 64])
    def test_bins_and_stride(self, bins, stride):
        rng = np.random.default_rng(bins * 10 + stride)
        image, bi, cb = make_case(rng, 4, 9, 9, 3, 3, 3, bins)
        got = pk.pasm_conv(image, bi, cb, stride=stride)
        want = ref.pasm_conv(image, bi, cb, stride=stride)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_multi_tile_grid(self):
        """T > TILE_T exercises >1 grid step along the pixel axis."""
        rng = np.random.default_rng(7)
        image, bi, cb = make_case(rng, 3, 20, 20, 3, 3, 2, 8)
        got = pk.pasm_conv(image, bi, cb, tile_t=64)
        want = ref.pasm_conv(image, bi, cb)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_pas_phase_only(self):
        rng = np.random.default_rng(8)
        image, bi, cb = make_case(rng, *PAPER_CASE)
        acc = pk.pas_accumulate(image, bi, PAPER_TILE.bins)
        for m in range(PAPER_TILE.kernels):
            want = ref.pasm_histogram(image, bi[m], PAPER_TILE.bins)
            np.testing.assert_allclose(acc[m], want, rtol=1e-4, atol=1e-4)

    def test_paper_fig6_example(self):
        """The worked example of Fig 4/6: result must be 98.8."""
        # 5 taps: image values and bin indices from the paper's figures.
        image = jnp.array([26.7, 3.4, 4.8, 17.7, 6.1], jnp.float32).reshape(5, 1, 1)
        bi = jnp.array([0, 1, 2, 3, 0], jnp.int32).reshape(1, 5, 1, 1)
        cb = jnp.array([1.7, 0.4, 1.3, 2.0], jnp.float32)
        got = pk.pasm_conv(image, bi, cb)
        # exact sum is 98.76; the paper reports it rounded to 98.8
        np.testing.assert_allclose(np.asarray(got).ravel(), [98.76], rtol=1e-5)
        # phase 1 bins: bin0 = 26.7 + 6.1 = 32.8
        acc = pk.pas_accumulate(image, bi, 4)
        np.testing.assert_allclose(
            np.asarray(acc).ravel(), [32.8, 3.4, 4.8, 17.7], rtol=1e-5
        )


class TestWsKernel:
    def test_paper_tile(self):
        rng = np.random.default_rng(5)
        image, bi, cb = make_case(rng, *PAPER_CASE)
        got = wk.ws_conv(image, bi, cb)
        want = ref.ws_conv(image, bi, cb)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_direct_kernel(self):
        rng = np.random.default_rng(6)
        image = jnp.asarray(rng.standard_normal((5, 7, 7)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((3, 5, 3, 3)), jnp.float32)
        got = wk.direct_conv(image, w)
        want = ref.direct_conv(image, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_ws_equals_pasm_kernelized(self):
        """Both Pallas variants must agree (paper §5.3, float tolerance)."""
        rng = np.random.default_rng(9)
        image, bi, cb = make_case(rng, *PAPER_CASE)
        np.testing.assert_allclose(
            wk.ws_conv(image, bi, cb),
            pk.pasm_conv(image, bi, cb),
            rtol=1e-4,
            atol=1e-4,
        )


@settings(max_examples=25, deadline=None)
@given(
    c=st.integers(1, 6),
    khw=st.integers(1, 3),
    extra=st.integers(0, 5),
    m=st.integers(1, 4),
    bins_log2=st.integers(1, 6),
    stride=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_pasm_kernel_hypothesis(c, khw, extra, m, bins_log2, stride, seed):
    """Property: Pallas PASM == oracle across random shape/bin/stride space."""
    bins = 2**bins_log2
    ih = iw = khw + extra + 1
    rng = np.random.default_rng(seed)
    image, bi, cb = make_case(rng, c, ih, iw, khw, khw, m, bins)
    got = pk.pasm_conv(image, bi, cb, stride=stride, tile_t=32)
    want = ref.pasm_conv(image, bi, cb, stride=stride)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 4),
    khw=st.integers(1, 3),
    extra=st.integers(0, 4),
    m=st.integers(1, 3),
    bins_log2=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_ws_kernel_hypothesis(c, khw, extra, m, bins_log2, seed):
    bins = 2**bins_log2
    ih = iw = khw + extra + 1
    rng = np.random.default_rng(seed)
    image, bi, cb = make_case(rng, c, ih, iw, khw, khw, m, bins)
    got = wk.ws_conv(image, bi, cb, tile_t=32)
    want = ref.ws_conv(image, bi, cb)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
