"""Layer-2 JAX model: weight-shared CNN forward pass calling the L1 kernels.

Two graphs are exported by ``aot.py``:

* ``pasm_tile`` / ``ws_tile`` / ``direct_tile`` — one convolution tile with
  the paper's §4 shapes (C=15, 5x5 image, 3x3 kernel, M=2).  These are the
  units the rust coordinator schedules, and the numerics cross-check for the
  cycle-accurate simulator.
* ``model_b{N}`` — the end-to-end digits CNN at fixed batch sizes
  (conv1 -> bias -> relu -> maxpool -> conv2 -> bias -> relu -> dense),
  with both conv layers dictionary-encoded and computed by the PASM kernel.

All parameters (codebooks, bin indices, dense weights) are runtime inputs of
the exported HLO, so the rust side can swap trained/quantized weights without
re-tracing — python never runs on the request path.
"""

from typing import Dict

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import pasm_conv as pk
from .kernels import ws_conv as wk
from .kernels import ref


def tile_forward_pasm(image, bin_idx, codebook):
    """Single PASM conv tile: the unit of work the coordinator dispatches."""
    return pk.pasm_conv(image, bin_idx, codebook)


def tile_forward_ws(image, bin_idx, codebook):
    """Weight-shared MAC baseline tile (identical numerics modulo fp order)."""
    return wk.ws_conv(image, bin_idx, codebook)


def tile_forward_direct(image, weights):
    """Non-weight-shared baseline tile."""
    return wk.direct_conv(image, weights)


def _sample_forward(cfg: ModelConfig, x, params: Dict[str, jax.Array], conv_fn):
    """Forward one [C,H,W] sample through the digits CNN."""
    h = conv_fn(x, params["bi1"], params["cb1"])  # [M1, 10, 10]
    h = ref.relu(h + params["bias1"][:, None, None])
    h = ref.maxpool2(h)  # [M1, 5, 5]
    h = conv_fn(h, params["bi2"], params["cb2"])  # [M2, 3, 3]
    h = ref.relu(h + params["bias2"][:, None, None])
    feat = h.reshape(-1)  # [feature_dim]
    return feat @ params["dense_w"] + params["dense_b"]  # [classes]


def model_forward(cfg: ModelConfig, images, params: Dict[str, jax.Array], variant: str = "pasm"):
    """Batched forward. images [N, C, H, W] -> logits [N, classes].

    The batch loop is a static python unroll: N is fixed per exported
    artifact (the coordinator buckets requests to the nearest batch size),
    and each iteration is one pallas_call chain, so XLA sees N independent
    subgraphs it can fuse and schedule freely.
    """
    conv_fn = {
        "pasm": tile_forward_pasm,
        "ws": tile_forward_ws,
    }[variant]
    logits = [
        _sample_forward(cfg, images[i], params, conv_fn)
        for i in range(images.shape[0])
    ]
    return jnp.stack(logits)


def model_param_specs(cfg: ModelConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Shape/dtype signature of the exported model parameters (manifest)."""
    c1, c2 = cfg.conv1, cfg.conv2
    f32, i32 = jnp.float32, jnp.int32
    return {
        "bi1": jax.ShapeDtypeStruct((c1.kernels, c1.channels, c1.kernel_h, c1.kernel_w), i32),
        "cb1": jax.ShapeDtypeStruct((cfg.bins,), f32),
        "bias1": jax.ShapeDtypeStruct((c1.kernels,), f32),
        "bi2": jax.ShapeDtypeStruct((c2.kernels, c2.channels, c2.kernel_h, c2.kernel_w), i32),
        "cb2": jax.ShapeDtypeStruct((cfg.bins,), f32),
        "bias2": jax.ShapeDtypeStruct((c2.kernels,), f32),
        "dense_w": jax.ShapeDtypeStruct((cfg.feature_dim, cfg.classes), f32),
        "dense_b": jax.ShapeDtypeStruct((cfg.classes,), f32),
    }


# Canonical parameter order for the exported HLO (rust marshals in this order).
PARAM_ORDER = ["bi1", "cb1", "bias1", "bi2", "cb2", "bias2", "dense_w", "dense_b"]


def model_forward_flat(cfg: ModelConfig, variant: str = "pasm"):
    """Return fn(images, *params_in_PARAM_ORDER) -> logits, for jit/lower."""

    def fn(images, *flat_params):
        params = dict(zip(PARAM_ORDER, flat_params))
        return model_forward(cfg, images, params, variant)

    return fn


def init_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    """Random float init + K-means quantization — a stand-in parameter set
    for shape tests and the artifact smoke path (the e2e example overwrites
    these with rust-trained weights)."""
    from . import quantize

    c1, c2 = cfg.conv1, cfg.conv2
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jax.random.normal(k1, (c1.kernels, c1.channels, c1.kernel_h, c1.kernel_w)) * 0.3
    w2 = jax.random.normal(k2, (c2.kernels, c2.channels, c2.kernel_h, c2.kernel_w)) * 0.2
    cb1, bi1 = quantize.quantize_weights(w1, cfg.bins)
    cb2, bi2 = quantize.quantize_weights(w2, cfg.bins)
    dense_w = jax.random.normal(k3, (cfg.feature_dim, cfg.classes)) * 0.1
    return {
        "bi1": bi1.astype(jnp.int32),
        "cb1": cb1,
        "bias1": jnp.zeros((c1.kernels,)),
        "bi2": bi2.astype(jnp.int32),
        "cb2": cb2,
        "bias2": jnp.zeros((c2.kernels,)),
        "dense_w": dense_w,
        "dense_b": jnp.zeros((cfg.classes,)),
    }
