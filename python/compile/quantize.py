"""K-means scalar weight quantizer (deep-compression style, Han et al. 2015).

The paper's weight sharing binning: cluster a trained layer's weights around
B centroids (Lloyd's algorithm), replace each weight with the index of its
nearest centroid, and keep the B centroid values as the layer codebook.

This module is build-time only: it quantizes the example model's weights so
``aot.py`` can bake codebook/bin-index example inputs into the pytest and the
artifact manifest.  The rust side has its own independent implementation in
``rust/src/quant/kmeans.rs`` (tested against the same invariants).
"""

from typing import Tuple

import jax
import jax.numpy as jnp


def _quantile_init(x: jax.Array, bins: int) -> jax.Array:
    """Initialise centroids at evenly spaced quantiles (deterministic,
    density-aware — matches how deep-compression seeds K-means)."""
    qs = (jnp.arange(bins, dtype=jnp.float32) + 0.5) / bins
    return jnp.quantile(x, qs)


def kmeans_1d(
    x: jax.Array, bins: int, iters: int = 30
) -> Tuple[jax.Array, jax.Array]:
    """Lloyd's K-means on a flat array.

    Returns ``(codebook [bins], assignments [x.size] int32)``.  Empty
    clusters keep their previous centroid (standard Lloyd's degenerate-case
    handling), so the codebook always has exactly ``bins`` entries — the
    hardware register file is a fixed size regardless of occupancy.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    centroids = _quantile_init(flat, bins)

    def step(c, _):
        d = jnp.abs(flat[:, None] - c[None, :])
        assign = jnp.argmin(d, axis=1)
        sums = jax.ops.segment_sum(flat, assign, num_segments=bins)
        counts = jax.ops.segment_sum(
            jnp.ones_like(flat), assign, num_segments=bins
        )
        c_new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), c)
        return c_new, None

    centroids, _ = jax.lax.scan(step, centroids, None, length=iters)
    assign = jnp.argmin(
        jnp.abs(flat[:, None] - centroids[None, :]), axis=1
    ).astype(jnp.int32)
    return centroids, assign


def quantize_weights(
    weights: jax.Array, bins: int, iters: int = 30
) -> Tuple[jax.Array, jax.Array]:
    """Quantize a [M,C,KY,KX] weight tensor to (codebook [B], bin_idx).

    ``codebook[bin_idx]`` is the dictionary-decoded approximation the
    weight-shared accelerator actually computes with.
    """
    codebook, assign = kmeans_1d(weights, bins, iters)
    return codebook, assign.reshape(weights.shape)


def quantization_mse(weights: jax.Array, bins: int, iters: int = 30) -> jax.Array:
    """Mean squared dictionary-encoding error — the metric deep compression
    trades against compression ratio."""
    codebook, bin_idx = quantize_weights(weights, bins, iters)
    return jnp.mean((codebook[bin_idx] - weights) ** 2)
