"""AOT compiler: lower the L2 graphs to HLO *text* artifacts for rust/PJRT.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids so text round-trips cleanly.  See
/opt/xla-example/README.md and gen_hlo.py.

Artifacts (all shapes fixed at lower time; rust reads ``manifest.json``):

    artifacts/pasm_tile.hlo.txt    PASM conv, paper tile  (image, bi, cb)
    artifacts/ws_tile.hlo.txt      weight-shared MAC conv, same signature
    artifacts/direct_tile.hlo.txt  dense conv             (image, weights)
    artifacts/model_b{N}.hlo.txt   digits CNN forward, batch N in {1,8,16}
    artifacts/manifest.json        shapes/dtypes/param order for rust

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import PAPER_TILE, E2E_MODEL, ConvTile


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tile_specs(tile: ConvTile):
    f32, i32 = jnp.float32, jnp.int32
    image = jax.ShapeDtypeStruct((tile.channels, tile.in_h, tile.in_w), f32)
    bi = jax.ShapeDtypeStruct(
        (tile.kernels, tile.channels, tile.kernel_h, tile.kernel_w), i32
    )
    cb = jax.ShapeDtypeStruct((tile.bins,), f32)
    weights = jax.ShapeDtypeStruct(
        (tile.kernels, tile.channels, tile.kernel_h, tile.kernel_w), f32
    )
    return image, bi, cb, weights


def lower_tiles(tile: ConvTile):
    """Lower the three accelerator-variant tile graphs."""
    image, bi, cb, weights = _tile_specs(tile)
    out = {}
    out["pasm_tile"] = jax.jit(M.tile_forward_pasm).lower(image, bi, cb)
    out["ws_tile"] = jax.jit(M.tile_forward_ws).lower(image, bi, cb)
    out["direct_tile"] = jax.jit(M.tile_forward_direct).lower(image, weights)
    return out


def lower_models(cfg):
    """Lower the e2e digits CNN at each batch-size bucket."""
    specs = M.model_param_specs(cfg)
    flat = [specs[k] for k in M.PARAM_ORDER]
    out = {}
    for n in cfg.batch_sizes:
        images = jax.ShapeDtypeStruct((n, cfg.in_c, cfg.in_h, cfg.in_w), jnp.float32)
        fn = M.model_forward_flat(cfg, variant="pasm")
        out[f"model_b{n}"] = jax.jit(fn).lower(images, *flat)
    return out


def build_manifest(tile: ConvTile, cfg) -> dict:
    specs = M.model_param_specs(cfg)
    return {
        "format": "hlo-text",
        "tile": tile.to_dict(),
        "model": cfg.to_dict(),
        "model_param_order": M.PARAM_ORDER,
        "model_params": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in specs.items()
        },
        "artifacts": {},
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--out",
        default=None,
        help="also write the pasm_tile HLO to this exact path (Makefile stamp)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    tile, cfg = PAPER_TILE, E2E_MODEL
    manifest = build_manifest(tile, cfg)

    lowered = {}
    lowered.update(lower_tiles(tile))
    lowered.update(lower_models(cfg))

    for name, low in lowered.items():
        text = to_hlo_text(low)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = f"{name}.hlo.txt"
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")

    if args.out:
        # Makefile stamp target: alias of pasm_tile.
        with open(args.out, "w") as f:
            f.write(to_hlo_text(lowered["pasm_tile"]))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
