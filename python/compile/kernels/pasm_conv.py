"""Layer-1 Pallas kernel: PASM convolution (PAS bin-accumulate + post-pass).

Hardware adaptation (DESIGN.md §2): the paper's PAS unit scatter-accumulates
each streamed image value into one of B register bins selected by the weight's
dictionary index, then a shared post-pass MAC contracts the B bins with the
codebook.  On TPU the scatter is re-expressed as a dense one-hot contraction
so the MXU does the binning:

    bins[t, b] = patches[t, k] @ onehot[k, b]        (PAS phase, MXU)
    out[t]     = bins[t, b]    @ codebook[b]         (post-pass,  VPU)

`onehot` has only B columns, so the contraction is tiny in the reduction
dimension — the TPU analogue of "the PAS is much smaller than the multiplier
array".  The [B]-bin accumulator tile and one patch tile live in VMEM (the
analogue of the paper's fully-partitioned ``imageBin`` register file).

All kernels run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default output-pixel tile.  8 sublanes x 128 lanes is the natural f32 TPU
# tile; the paper tile (T = 9 output pixels) pads up to one tile.
DEFAULT_TILE_T = 128


def _pasm_kernel(patches_ref, onehot_ref, codebook_ref, out_ref):
    """One (m, t-tile) grid step.

    patches_ref  [TILE_T, CKK]  image taps for TILE_T output pixels (VMEM)
    onehot_ref   [1, CKK, B]    tap -> bin selection matrix for kernel m
    codebook_ref [B, 1]         shared dictionary weights
    out_ref      [1, TILE_T]    output feature map slice for kernel m
    """
    patches = patches_ref[...]
    onehot = onehot_ref[0]
    # PAS phase: weighted histogram of dictionary indices (MXU contraction).
    bins = jnp.dot(patches, onehot, preferred_element_type=jnp.float32)
    # Post-pass MAC: B-length dot per output pixel.
    out = jnp.dot(bins, codebook_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = out.T


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    t = x.shape[0]
    pad = (-t) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


@functools.partial(jax.jit, static_argnames=("stride", "tile_t"))
def pasm_conv(
    image: jax.Array,
    bin_idx: jax.Array,
    codebook: jax.Array,
    stride: int = 1,
    tile_t: int = DEFAULT_TILE_T,
) -> jax.Array:
    """PASM convolution via the Pallas kernel.

    image    [C, IH, IW] f32
    bin_idx  [M, C, KY, KX] int32 in [0, B)
    codebook [B] f32
    returns  [M, OH, OW] f32
    """
    m, c, ky, kx = bin_idx.shape
    bins = codebook.shape[0]
    oh = (image.shape[1] - ky) // stride + 1
    ow = (image.shape[2] - kx) // stride + 1
    t = oh * ow
    ckk = c * ky * kx

    patches = ref.im2col(image, ky, kx, stride)  # [T, CKK]
    patches = _pad_rows(patches, tile_t)  # [Tp, CKK]
    tp = patches.shape[0]
    onehot = ref.one_hot_taps(bin_idx, bins)  # [M, CKK, B]
    cb = codebook.reshape(bins, 1)

    grid = (m, tp // tile_t)
    out = pl.pallas_call(
        _pasm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, ckk), lambda mi, ti: (ti, 0)),
            pl.BlockSpec((1, ckk, bins), lambda mi, ti: (mi, 0, 0)),
            pl.BlockSpec((bins, 1), lambda mi, ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_t), lambda mi, ti: (mi, ti)),
        out_shape=jax.ShapeDtypeStruct((m, tp), jnp.float32),
        interpret=True,
    )(patches, onehot, cb)

    return out[:, :t].reshape(m, oh, ow)


def _pas_only_kernel(patches_ref, onehot_ref, acc_ref):
    """PAS phase only — exposes the bin accumulator for inspection/tests."""
    acc_ref[0] = jnp.dot(
        patches_ref[...], onehot_ref[0], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bins", "stride", "tile_t"))
def pas_accumulate(
    image: jax.Array,
    bin_idx: jax.Array,
    bins: int,
    stride: int = 1,
    tile_t: int = DEFAULT_TILE_T,
) -> jax.Array:
    """Phase 1 only: [M, OH*OW, B] accumulated image values per bin.

    Matches :func:`ref.pasm_histogram` per kernel plane; used by pytest to
    validate the PAS dataflow in isolation (paper Fig 6a).
    """
    m, c, ky, kx = bin_idx.shape
    oh = (image.shape[1] - ky) // stride + 1
    ow = (image.shape[2] - kx) // stride + 1
    t = oh * ow
    ckk = c * ky * kx

    patches = _pad_rows(ref.im2col(image, ky, kx, stride), tile_t)
    tp = patches.shape[0]
    onehot = ref.one_hot_taps(bin_idx, bins)

    acc = pl.pallas_call(
        _pas_only_kernel,
        grid=(m, tp // tile_t),
        in_specs=[
            pl.BlockSpec((tile_t, ckk), lambda mi, ti: (ti, 0)),
            pl.BlockSpec((1, ckk, bins), lambda mi, ti: (mi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_t, bins), lambda mi, ti: (mi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((m, tp, bins), jnp.float32),
        interpret=True,
    )(patches, onehot)
    return acc[:, :t, :]


def vmem_footprint_bytes(
    ckk: int, bins: int, tile_t: int = DEFAULT_TILE_T, dtype_bytes: int = 4
) -> int:
    """Estimated VMEM bytes for one kernel grid step (DESIGN.md §8).

    patches tile + one-hot plane + codebook + bin accumulator + output tile.
    """
    patches = tile_t * ckk
    onehot = ckk * bins
    codebook = bins
    acc = tile_t * bins
    out = tile_t
    return (patches + onehot + codebook + acc + out) * dtype_bytes


def mxu_utilization_estimate(ckk: int, bins: int, tile_t: int = DEFAULT_TILE_T) -> float:
    """Fraction of 128x128 MXU lanes doing useful work in the PAS matmul.

    The contraction is [TILE_T, CKK] @ [CKK, B]: the B (<=256) output columns
    under-fill the 128-lane axis when B < 128 — the structural price of the
    one-hot formulation, amortized because B << CKK (paper Table 2 regime).
    """
    lane_fill = min(bins, 128) / 128.0
    sublane_fill = min(tile_t, 128) / 128.0
    return lane_fill * sublane_fill
