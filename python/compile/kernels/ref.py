"""Pure-jnp oracles for the convolution variants.

These are the CORE correctness signal for the Pallas kernels: every kernel in
this package must ``allclose`` against its oracle here (pytest enforces it).

The three variants mirror the paper:

* :func:`direct_conv`      — Fig 1 pseudo-code, plain sum-of-products.
* :func:`ws_conv`          — Fig 3/4, weight-shared MAC: decode the codebook
                             through the bin index, then multiply-accumulate.
* :func:`pasm_conv`        — Fig 5/6, PASM: phase 1 accumulates image values
                             into B bins keyed by bin index (a weighted
                             histogram of dictionary indices), phase 2
                             multiplies each bin by its codebook weight.

Over the reals the three are identical permutations of the same sum; in
floating point they agree to ``allclose`` tolerance, and in the rust
fixed-point simulator they are bit-exact (paper §5.3).
"""

import jax
import jax.numpy as jnp


def im2col(image: jax.Array, ky: int, kx: int, stride: int = 1) -> jax.Array:
    """[C, IH, IW] -> patches [OH*OW, C*KY*KX] with (c, ky, kx) tap order.

    The tap order matches the flattening of ``bin_idx[m, c, ky, kx]`` so that
    patch column ``c*KY*KX + ky*KX + kx`` pairs with that tap's bin index.
    Static python loops over the (small) kernel window unroll at trace time.
    """
    c, ih, iw = image.shape
    oh = (ih - ky) // stride + 1
    ow = (iw - kx) // stride + 1
    cols = []
    for y in range(ky):
        for x in range(kx):
            sl = jax.lax.slice(
                image,
                (0, y, x),
                (c, y + (oh - 1) * stride + 1, x + (ow - 1) * stride + 1),
                (1, stride, stride),
            )  # [C, OH, OW]
            cols.append(sl)
    # [C, KY*KX, OH, OW] -> [C*KY*KX, OH*OW] -> [OH*OW, C*KY*KX]
    p = jnp.stack(cols, axis=1)
    return p.reshape(c * ky * kx, oh * ow).T


def direct_conv(image: jax.Array, weights: jax.Array, stride: int = 1) -> jax.Array:
    """Plain convolution. image [C,IH,IW], weights [M,C,KY,KX] -> [M,OH,OW]."""
    m, c, ky, kx = weights.shape
    patches = im2col(image, ky, kx, stride)  # [T, CKK]
    w = weights.reshape(m, c * ky * kx)  # [M, CKK]
    out = patches @ w.T  # [T, M]
    oh = (image.shape[1] - ky) // stride + 1
    ow = (image.shape[2] - kx) // stride + 1
    return out.T.reshape(m, oh, ow)


def decode_weights(bin_idx: jax.Array, codebook: jax.Array) -> jax.Array:
    """Dictionary-decode weight-shared indices: w[m,c,ky,kx] = codebook[bi]."""
    return codebook[bin_idx]


def ws_conv(
    image: jax.Array, bin_idx: jax.Array, codebook: jax.Array, stride: int = 1
) -> jax.Array:
    """Weight-shared MAC convolution (decode-then-MAC, Fig 3/4)."""
    return direct_conv(image, decode_weights(bin_idx, codebook), stride)


def one_hot_taps(bin_idx: jax.Array, bins: int) -> jax.Array:
    """[M,C,KY,KX] int32 -> one-hot [M, C*KY*KX, B] float32.

    Row t of plane m selects the bin that tap t's image value accumulates
    into — the dataflow of the PAS unit expressed as a dense selection
    matrix (the TPU adaptation of the paper's counting/selection logic,
    DESIGN.md §2).
    """
    m = bin_idx.shape[0]
    flat = bin_idx.reshape(m, -1)
    return jax.nn.one_hot(flat, bins, dtype=jnp.float32)


def pasm_conv(
    image: jax.Array, bin_idx: jax.Array, codebook: jax.Array, stride: int = 1
) -> jax.Array:
    """PASM convolution: bin-accumulate (PAS) then post-pass multiply.

    Phase 1: bins[t_out, b] = sum over taps whose index == b of the image
    value at that tap  (patches @ one_hot)  — the weighted histogram.
    Phase 2: out = bins @ codebook — the shared post-pass MAC.
    """
    m, c, ky, kx = bin_idx.shape
    bins = codebook.shape[0]
    patches = im2col(image, ky, kx, stride)  # [T, CKK]
    onehot = one_hot_taps(bin_idx, bins)  # [M, CKK, B]
    # per-m: [T, CKK] @ [CKK, B] -> [T, B]; then [T, B] @ [B] -> [T]
    acc = jnp.einsum("tk,mkb->mtb", patches, onehot)  # PAS phase
    out = acc @ codebook  # post-pass MAC  [M, T]
    oh = (image.shape[1] - ky) // stride + 1
    ow = (image.shape[2] - kx) // stride + 1
    return out.reshape(m, oh, ow)


def pasm_histogram(
    image: jax.Array, bin_idx_m: jax.Array, bins: int, stride: int = 1
) -> jax.Array:
    """Phase-1-only oracle via segment_sum (independent of the one-hot path).

    Returns [OH*OW, B] accumulated image values for a single kernel plane
    ``bin_idx_m`` [C,KY,KX].  Used by tests to cross-check the one-hot
    formulation against a genuinely different implementation.
    """
    c, ky, kx = bin_idx_m.shape
    patches = im2col(image, ky, kx, stride)  # [T, CKK]
    flat = bin_idx_m.reshape(-1)  # [CKK]

    def per_row(row):
        return jax.ops.segment_sum(row, flat, num_segments=bins)

    return jax.vmap(per_row)(patches)


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2 stride-2 VALID max-pool over [C,H,W]."""
    c, h, w = x.shape
    x = x[:, : h // 2 * 2, : w // 2 * 2]
    x = x.reshape(c, h // 2, 2, w // 2, 2)
    return x.max(axis=(2, 4))
