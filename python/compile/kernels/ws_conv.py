"""Layer-1 Pallas kernels for the two baseline accelerators.

* :func:`ws_conv` — the weight-shared MAC baseline (paper Fig 3/4): decode
  the codebook through the bin indices, then a plain sum-of-products.  The
  decode is the ``onehot @ codebook`` contraction (the register-file read
  through the index), the SOP is the big ``patches @ w`` matmul — exactly the
  structure whose multiplier array PASM removes.
* :func:`direct_conv` — the non-weight-shared baseline (paper Fig 1/2):
  dense weights, plain sum-of-products.

Both run under ``interpret=True`` (see pasm_conv.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .pasm_conv import DEFAULT_TILE_T, _pad_rows


def _ws_kernel(patches_ref, onehot_ref, codebook_ref, out_ref):
    """Weight-shared MAC: decode then multiply-accumulate.

    The decode (`onehot @ codebook`) models the weight-register-file read of
    Fig 3; the second matmul is the full W-bit multiplier array that the
    paper's PASM replaces.
    """
    w = jnp.dot(
        onehot_ref[0], codebook_ref[...], preferred_element_type=jnp.float32
    )  # [CKK, 1] decoded weights for kernel m
    out = jnp.dot(patches_ref[...], w, preferred_element_type=jnp.float32)
    out_ref[...] = out.T


@functools.partial(jax.jit, static_argnames=("stride", "tile_t"))
def ws_conv(
    image: jax.Array,
    bin_idx: jax.Array,
    codebook: jax.Array,
    stride: int = 1,
    tile_t: int = DEFAULT_TILE_T,
) -> jax.Array:
    """Weight-shared MAC convolution via Pallas. Same signature as pasm_conv."""
    m, c, ky, kx = bin_idx.shape
    bins = codebook.shape[0]
    oh = (image.shape[1] - ky) // stride + 1
    ow = (image.shape[2] - kx) // stride + 1
    t = oh * ow
    ckk = c * ky * kx

    patches = _pad_rows(ref.im2col(image, ky, kx, stride), tile_t)
    tp = patches.shape[0]
    onehot = ref.one_hot_taps(bin_idx, bins)
    cb = codebook.reshape(bins, 1)

    out = pl.pallas_call(
        _ws_kernel,
        grid=(m, tp // tile_t),
        in_specs=[
            pl.BlockSpec((tile_t, ckk), lambda mi, ti: (ti, 0)),
            pl.BlockSpec((1, ckk, bins), lambda mi, ti: (mi, 0, 0)),
            pl.BlockSpec((bins, 1), lambda mi, ti: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_t), lambda mi, ti: (mi, ti)),
        out_shape=jax.ShapeDtypeStruct((m, tp), jnp.float32),
        interpret=True,
    )(patches, onehot, cb)
    return out[:, :t].reshape(m, oh, ow)


def _direct_kernel(patches_ref, weights_ref, out_ref):
    w = weights_ref[...].reshape(-1, 1)  # [CKK, 1] weights for kernel m
    out = jnp.dot(patches_ref[...], w, preferred_element_type=jnp.float32)
    out_ref[...] = out.T  # [1, TILE_T]


@functools.partial(jax.jit, static_argnames=("stride", "tile_t"))
def direct_conv(
    image: jax.Array,
    weights: jax.Array,
    stride: int = 1,
    tile_t: int = DEFAULT_TILE_T,
) -> jax.Array:
    """Non-weight-shared convolution via Pallas. weights [M,C,KY,KX]."""
    m, c, ky, kx = weights.shape
    oh = (image.shape[1] - ky) // stride + 1
    ow = (image.shape[2] - kx) // stride + 1
    t = oh * ow
    ckk = c * ky * kx

    patches = _pad_rows(ref.im2col(image, ky, kx, stride), tile_t)
    tp = patches.shape[0]
    wflat = weights.reshape(m, ckk)

    out = pl.pallas_call(
        _direct_kernel,
        grid=(m, tp // tile_t),
        in_specs=[
            pl.BlockSpec((tile_t, ckk), lambda mi, ti: (ti, 0)),
            pl.BlockSpec((1, ckk), lambda mi, ti: (mi, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_t), lambda mi, ti: (mi, ti)),
        out_shape=jax.ShapeDtypeStruct((m, tp), jnp.float32),
        interpret=True,
    )(patches, wflat)
    return out[:, :t].reshape(m, oh, ow)
