"""Shared shape configurations for the PASM reproduction.

These mirror the paper's experimental setups:

* ``PAPER_TILE`` — the conv-layer tile used throughout §4/§5 of the paper
  (IH = IW = 5, C = 15, KX = KY = 3, M = 2), sized so the image cache fits a
  register file.  All ASIC/FPGA figures (15-22) use this tile.
* ``E2E_MODEL`` — the tiny CNN used by the end-to-end inference example
  (synthetic 12x12 digits, two PASM conv layers, a dense head).

Both the python (L1/L2) and rust (L3) sides consume the artifact manifest
emitted by ``aot.py``, which is generated from these dataclasses — the rust
side never hard-codes shapes.
"""

from dataclasses import dataclass, asdict, field
from typing import List


@dataclass(frozen=True)
class ConvTile:
    """A single weight-shared convolution tile (one grid position batch)."""

    name: str
    channels: int  # C
    in_h: int  # IH
    in_w: int  # IW
    kernel_h: int  # KY
    kernel_w: int  # KX
    kernels: int  # M (output channels)
    bins: int  # B (codebook entries)
    stride: int = 1

    @property
    def out_h(self) -> int:
        return (self.in_h - self.kernel_h) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.in_w - self.kernel_w) // self.stride + 1

    @property
    def taps(self) -> int:
        """MAC operations per output element: N = C * KY * KX (paper §4)."""
        return self.channels * self.kernel_h * self.kernel_w

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(out_h=self.out_h, out_w=self.out_w, taps=self.taps)
        return d


@dataclass(frozen=True)
class ModelConfig:
    """Tiny CNN for the end-to-end example: conv-relu-pool x2 + dense."""

    name: str = "digits-cnn"
    in_h: int = 12
    in_w: int = 12
    in_c: int = 1
    conv1_m: int = 8
    conv2_m: int = 16
    kernel: int = 3
    bins: int = 16
    classes: int = 10
    batch_sizes: tuple = (1, 8, 16)

    @property
    def conv1(self) -> ConvTile:
        return ConvTile(
            name="conv1",
            channels=self.in_c,
            in_h=self.in_h,
            in_w=self.in_w,
            kernel_h=self.kernel,
            kernel_w=self.kernel,
            kernels=self.conv1_m,
            bins=self.bins,
        )

    @property
    def pool1_hw(self) -> int:
        return self.conv1.out_h // 2  # 2x2 maxpool, VALID

    @property
    def conv2(self) -> ConvTile:
        return ConvTile(
            name="conv2",
            channels=self.conv1_m,
            in_h=self.pool1_hw,
            in_w=self.pool1_hw,
            kernel_h=self.kernel,
            kernel_w=self.kernel,
            kernels=self.conv2_m,
            bins=self.bins,
        )

    @property
    def feature_dim(self) -> int:
        return self.conv2_m * self.conv2.out_h * self.conv2.out_w

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "in_h": self.in_h,
            "in_w": self.in_w,
            "in_c": self.in_c,
            "kernel": self.kernel,
            "bins": self.bins,
            "classes": self.classes,
            "batch_sizes": list(self.batch_sizes),
            "conv1": self.conv1.to_dict(),
            "conv2": self.conv2.to_dict(),
            "pool1_hw": self.pool1_hw,
            "feature_dim": self.feature_dim,
        }


# The paper's conv-accelerator tile (§4: IH=5, IW=5, C=15, KY=KX=3, M=2).
PAPER_TILE = ConvTile(
    name="paper_tile",
    channels=15,
    in_h=5,
    in_w=5,
    kernel_h=3,
    kernel_w=3,
    kernels=2,
    bins=16,
)

# Bin sweep used in figures 14-17 / 19-21.
PAPER_TILE_BINS: List[int] = [4, 8, 16]

E2E_MODEL = ModelConfig()
