//! Latency-under-load study: open-loop Poisson arrivals against the
//! coordinator at increasing offered rates — the standard serving curve
//! (latency stays flat until the knee, then queueing blows it up).
//!
//! Runs on the in-process [`NativeBackend`] by default; build with
//! `--features pjrt` (after `make artifacts`) for the PJRT/Pallas model.
//!
//! ```bash
//! cargo run --release --example latency_under_load
//! ```

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::loadgen::run_open_loop;
use pasm_accel::coordinator::{default_backend, BatchPolicy, CoordinatorBuilder};
use pasm_accel::quant::fixed::QFormat;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(61);
    let params = arch.init(&mut rng);
    let enc = EncodedCnn::encode(arch, &params, 16, QFormat::W32);

    let coord = CoordinatorBuilder::new()
        .boxed_backend(default_backend("artifacts", enc))
        .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2)))
        .build()?;

    let pool: Vec<_> = (0..64).map(|i| render_digit(&mut rng, i % 10, 0.05)).collect();

    // capacity probe: blast a closed burst to find max throughput
    let burst = 512;
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..burst)
        .map(|i| coord.submit(pool[i % pool.len()].clone()).unwrap())
        .collect();
    for rx in rxs {
        rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
    }
    let capacity = burst as f64 / t0.elapsed().as_secs_f64();
    println!(
        "capacity probe ({} backend): ~{capacity:.0} req/s (burst, full batches)\n",
        coord.metrics().backend
    );

    println!(
        "{:>9} {:>10} {:>9} {:>9} {:>9} {:>9} {:>7}",
        "offered", "achieved", "mean", "p50", "p90", "p99", "errors"
    );
    for frac in [0.1, 0.25, 0.5, 0.7, 0.85] {
        let rate = capacity * frac;
        let n = (rate * 2.0).max(64.0) as usize; // ~2 seconds of load
        let r = run_open_loop(&coord, &pool, n, rate, &mut rng);
        println!(
            "{:>7.0}/s {:>8.0}/s {:>7.1}ms {:>7.1}ms {:>7.1}ms {:>7.1}ms {:>7}",
            r.offered_hz,
            r.achieved_hz,
            r.mean_us() / 1e3,
            r.percentile_us(50.0) as f64 / 1e3,
            r.percentile_us(90.0) as f64 / 1e3,
            r.percentile_us(99.0) as f64 / 1e3,
            r.errors
        );
        assert_eq!(r.errors, 0, "no request may be lost");
    }

    let m = coord.metrics();
    println!(
        "\ntotals: {} requests, {} batches, mean occupancy {:.1}, padding {:.1}%",
        m.requests,
        m.batches,
        m.mean_occupancy(),
        m.padding_fraction() * 100.0
    );
    Ok(())
}
