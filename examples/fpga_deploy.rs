//! FPGA deployment study (paper §5.2): map all three accelerator variants
//! onto the Zynq XC7Z045 (ZC706) and the resource-constrained XC7Z020
//! (PYNQ-Z1), reproducing the paper's "the WS design over-utilizes the
//! PYNQ's 220 DSPs; PASM fits with 3" result.
//!
//! ```bash
//! cargo run --release --example fpga_deploy
//! ```

use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind};
use pasm_accel::fpga::{fpga_power, map_conv_accel, Device};

fn main() {
    let devices = [Device::xc7z045(), Device::xc7z020()];
    let variants = [
        ("non-weight-shared", ConvVariantKind::Direct),
        ("weight-shared", ConvVariantKind::WeightShared),
        ("weight-shared+PASM", ConvVariantKind::Pasm),
    ];

    for dev in &devices {
        println!("=== {} (LUT {}, FF {}, BRAM18 {}, DSP {}) @200 MHz ===",
            dev.name, dev.luts, dev.ffs, dev.bram18, dev.dsp);
        for bins in [4usize, 8, 16] {
            for (name, variant) in variants {
                let design = map_conv_accel(&ConvAccel::paper(variant, bins, 32));
                let p = fpga_power(&design, dev);
                let fits = design.util.fits(dev);
                let worst = design
                    .util
                    .fractions(dev)
                    .into_iter()
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap();
                println!(
                    "  {bins:>2}-bin {name:<20} DSP {:>4}  BRAM {:>3}  LUT {:>7}  {:>8.0} mW  {}",
                    design.util.dsp,
                    design.util.bram18,
                    design.util.luts,
                    p.total_w() * 1e3,
                    if fits {
                        format!("fits ({} {:.0}% worst)", worst.0, worst.1 * 100.0)
                    } else {
                        format!("DOES NOT FIT ({} {:.0}%)", worst.0, worst.1 * 100.0)
                    }
                );
            }
        }
        println!();
    }

    // the paper's headline sentence, checked programmatically
    let z20 = Device::xc7z020();
    let ws = map_conv_accel(&ConvAccel::paper(ConvVariantKind::WeightShared, 4, 32));
    let pasm = map_conv_accel(&ConvAccel::paper(ConvVariantKind::Pasm, 4, 32));
    assert!(!ws.util.fits(&z20), "WS should over-utilize the XC7Z020");
    assert!(pasm.util.fits(&z20), "PASM should fit the XC7Z020");
    println!(
        "paper §5.2 reproduced: WS needs {} DSPs (> {} available on {}), PASM needs {}",
        ws.util.dsp, z20.dsp, z20.name, pasm.util.dsp
    );
}
