//! Serving demo: run the coordinator as a service and fire batched load
//! from multiple client threads, reporting latency/throughput percentiles
//! and the simulated PASM accelerator cost.
//!
//! Serves on the in-process [`NativeBackend`] by default (no artifacts
//! needed); build with `--features pjrt` (after `make artifacts`) to serve
//! the AOT-compiled PJRT/Pallas model instead.
//!
//! ```bash
//! cargo run --release --example serve -- 4 200
//! #       client threads ----^   ^---- requests each
//! ```

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{default_backend, BatchPolicy, CoordinatorBuilder};
use pasm_accel::quant::fixed::QFormat;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let threads: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let per_thread: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100);

    let arch = DigitsCnn::default();
    let mut rng = Rng::new(5);
    let params = arch.init(&mut rng);
    let enc = EncodedCnn::encode(arch, &params, 16, QFormat::W32);

    let coord = Arc::new(
        CoordinatorBuilder::new()
            .boxed_backend(default_backend("artifacts", enc))
            .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2)))
            .build()?,
    );
    println!(
        "coordinator up ({} backend); {threads} clients x {per_thread} requests",
        coord.metrics().backend
    );

    let t0 = Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let coord = Arc::clone(&coord);
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t as u64);
                let mut ok = 0usize;
                for i in 0..per_thread {
                    let img = render_digit(&mut rng, (t + i) % 10, 0.05);
                    if coord.infer(img).is_ok() {
                        ok += 1;
                    }
                }
                ok
            })
        })
        .collect();
    let ok: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t0.elapsed();
    let total = threads * per_thread;

    println!(
        "served {ok}/{total} in {dt:?} -> {:.1} req/s",
        total as f64 / dt.as_secs_f64()
    );
    let m = coord.metrics();
    println!(
        "batches {} | mean occupancy {:.2} | padding {:.1}%",
        m.batches,
        m.mean_occupancy(),
        m.padding_fraction() * 100.0
    );
    for p in [50.0, 90.0, 99.0] {
        println!("p{p:.0} latency: {} us", m.percentile_us(p).unwrap());
    }
    println!(
        "simulated accelerator: {} cycles, {:.3} uJ ({:.2} nJ/req)",
        m.sim_cycles,
        m.sim_energy_j * 1e6,
        m.sim_energy_j * 1e9 / ok.max(1) as f64
    );
    Ok(())
}
