//! Quickstart: build a weight-shared-with-PASM convolution accelerator,
//! run one tile through the cycle-accurate simulator, and price it on the
//! 45 nm ASIC model — the paper's pipeline in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind};
use pasm_accel::cnn::conv::FxConvInputs;
use pasm_accel::cnn::data::Rng;
use pasm_accel::hw::Tech;
use pasm_accel::quant::codebook::encode_weights;
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::sim::simulate_conv;
use pasm_accel::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    // 1) a trained-looking conv layer (the paper tile: C=15, 5x5, 3x3, M=2)
    let mut rng = Rng::new(7);
    let image = Tensor::from_fn(&[15, 5, 5], |_| rng.signed() * 4.0);
    let weights = Tensor::from_fn(&[2, 15, 3, 3], |_| rng.signed());

    // 2) weight sharing: K-means the weights into B=16 dictionary bins
    let encoded = encode_weights(&weights, 16, QFormat::W32);
    println!(
        "codebook: {} bins, {:.1}x index compression, kmeans mse {:.2e}",
        encoded.codebook.bins(),
        encoded.index_compression(),
        encoded.mse
    );

    // 3) the PASM accelerator for that layer
    let accel = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32);
    let baseline = ConvAccel::paper(ConvVariantKind::WeightShared, 16, 32);

    // 4) run the tile through the cycle-accurate simulator (bit-exact
    //    fixed-point dataflow, identical results to the WS baseline)
    let inputs = FxConvInputs::encode(&image, &encoded, QFormat::IMAGE32, 1);
    let sim = simulate_conv(&accel, &inputs);
    let sim_ws = simulate_conv(&baseline, &inputs);
    assert_eq!(sim.out.data(), sim_ws.out.data(), "paper §5.3: identical results");
    println!(
        "simulated: {} cycles (WS baseline {}), outputs bit-exact",
        sim.cycles, sim_ws.cycles
    );

    // 5) price both on the 45 nm ASIC model at 1 GHz
    let tech = Tech::asic_1ghz();
    for (name, a) in [("weight-shared", &baseline), ("PASM", &accel)] {
        let g = a.gates(&tech);
        let p = a.power(&tech);
        println!(
            "{name:>14}: {:>9.0} NAND2 gates, {:>7.2} mW, {} cycles",
            g.total(),
            p.total_w() * 1e3,
            a.latency_cycles()
        );
    }
    let saving = 1.0
        - accel.power(&tech).total_w() / baseline.power(&tech).total_w();
    println!("PASM power saving: {:.1}%", saving * 100.0);
    Ok(())
}
