//! Network serving end to end, in one process: build a registry with two
//! quantization variants, front it with the TCP serving layer on an
//! ephemeral port, drive both model ids over real sockets, hot-swap one
//! variant mid-run, and print the combined metrics frame.
//!
//! ```bash
//! cargo run --release --example net_serving
//! ```

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{BatchPolicy, CoordinatorBuilder};
use pasm_accel::model_store::ModelRegistry;
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::serving::{Client, Server, ServerConfig};
use std::sync::Arc;
use std::time::Duration;

fn encoded(seed: u64, bins: usize) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, bins, QFormat::W32)
}

fn main() -> anyhow::Result<()> {
    // model store: two variants of the digits model at different B
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("digits-b8", encoded(1, 8));
    registry.insert("digits-b16", encoded(2, 16));

    // coordinator + TCP front-end on an ephemeral port
    let coord = Arc::new(
        CoordinatorBuilder::new()
            .registry(Arc::clone(&registry))
            .batch_policy(BatchPolicy::new(vec![1, 8], Duration::from_millis(2)))
            .build()?,
    );
    let server = Server::bind("127.0.0.1:0", Arc::clone(&coord), ServerConfig::default())?;
    let addr = server.local_addr();
    println!("serving on {addr}");

    // two clients, one per model id, over real sockets
    let n = 32usize;
    std::thread::scope(|scope| {
        for (model, seed) in [("digits-b8", 10u64), ("digits-b16", 20u64)] {
            scope.spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut rng = Rng::new(seed);
                for i in 0..n {
                    let img = render_digit(&mut rng, i % 10, 0.05);
                    let reply = client.infer(Some(model), &img).expect("infer");
                    assert_eq!(reply.model.as_deref(), Some(model));
                }
                println!("client for {model}: {n} replies ok");
            });
        }
    });

    // hot-swap digits-b8 to a new encoding; the next request serves it
    let mut client = Client::connect(addr)?;
    let probe = render_digit(&mut Rng::new(3), 7, 0.05);
    let before = client.infer(Some("digits-b8"), &probe).map_err(|e| anyhow::anyhow!("{e}"))?;
    registry.insert("digits-b8", encoded(9, 4));
    let after = client.infer(Some("digits-b8"), &probe).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "hot-swap: logits changed = {} (B=8 -> B=4 re-encode, no restart)",
        before.logits != after.logits
    );

    let models = client.list_models().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("models: {:?} (default {:?})", models.models, models.default);
    let m = client.metrics().map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "metrics: backend '{}', {} requests in {} batches; net: {} conns, {} frames in, {} ok",
        m.backend,
        m.requests,
        m.batches,
        m.net.connections_opened,
        m.net.frames_received,
        m.net.requests_ok
    );
    for (name, c) in &m.per_model {
        println!("  model {name}: {} requests in {} batches", c.requests, c.batches);
    }
    Ok(())
}
