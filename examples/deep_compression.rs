//! The full deep-compression chain (Han et al., the paper's §2.1
//! precondition) on the digits CNN: train → magnitude-prune (+ masked
//! retraining) → K-means weight sharing → Huffman-code the index stream,
//! reporting accuracy and compression factor at each stage, plus the
//! weight-traffic energy (DRAM vs SRAM residence — the 640 pJ vs 5 pJ
//! motivation the paper opens with).
//!
//! ```bash
//! cargo run --release --example deep_compression
//! ```

use pasm_accel::cnn::data::{train_test, Rng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::cnn::train::{train, TrainConfig};
use pasm_accel::hw::memenergy::{
    fits_on_chip, weight_stream_energy, Residence, WeightFormat,
};
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::quant::huffman;
use pasm_accel::quant::prune::magnitude_prune;
use pasm_accel::tensor::ConvShape;

fn main() {
    // ---- stage 0: train ----
    let (train_set, test_set) = train_test(99, 600, 200, 0.05);
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(31);
    let mut params = arch.init(&mut rng);
    let cfg = TrainConfig { epochs: 25, lr: 0.05, momentum: 0.9, log_every: 0 };
    train(&arch, &mut params, &train_set, &cfg);
    let acc0 = arch.accuracy(&params, &test_set);
    println!("stage 0  trained float:            accuracy {:.1}%", acc0 * 100.0);

    // ---- stage 1: magnitude prune 50% of conv weights + masked retrain ----
    let prune_frac = 0.5;
    let m1 = magnitude_prune(&params.conv1_w, prune_frac);
    let m2 = magnitude_prune(&params.conv2_w, prune_frac);
    m1.apply(&mut params.conv1_w);
    m2.apply(&mut params.conv2_w);
    let acc_pruned_raw = arch.accuracy(&params, &test_set);
    // brief retraining with the mask re-applied after each epoch
    let retrain = TrainConfig { epochs: 6, lr: 0.02, momentum: 0.9, log_every: 0 };
    for _ in 0..retrain.epochs {
        let one = TrainConfig { epochs: 1, ..retrain };
        train(&arch, &mut params, &train_set, &one);
        m1.apply(&mut params.conv1_w);
        m2.apply(&mut params.conv2_w);
    }
    let acc1 = arch.accuracy(&params, &test_set);
    println!(
        "stage 1  pruned {:.0}% (+retrain):   accuracy {:.1}% (raw after prune {:.1}%)",
        prune_frac * 100.0,
        acc1 * 100.0,
        acc_pruned_raw * 100.0
    );

    // ---- stage 2: K-means weight sharing ----
    let bins = 16;
    let enc = EncodedCnn::encode(arch, &params, bins, QFormat::W32);
    let acc2 = enc.accuracy(&test_set, ConvVariant::Pasm);
    println!(
        "stage 2  weight-shared B={bins}:      accuracy {:.1}% (PASM dataflow)",
        acc2 * 100.0
    );

    // ---- stage 3: Huffman-code the conv2 index stream ----
    let occupancy = enc.conv2.occupancy();
    let code = huffman::build(&occupancy).expect("conv2 occupancy is a valid histogram");
    let mean_bits = code.mean_bits(&occupancy);
    let entropy = huffman::entropy_bits(&occupancy);
    // roundtrip sanity on the real stream
    let stream: Vec<u16> = enc.conv2.bin_idx.data().to_vec();
    let bits = code.encode(&stream).expect("every live bin has a code");
    assert_eq!(code.decode(&bits, stream.len()).expect("roundtrip decode"), stream);
    println!(
        "stage 3  huffman indices:          {:.2} bits/weight (entropy {:.2}, fixed {} bits)",
        mean_bits,
        entropy,
        enc.conv2.codebook.index_bits()
    );

    // ---- compression + energy accounting (conv2 layer) ----
    let shape = ConvShape::new(8, 5, 5, 3, 3, 16, 1); // conv2 of the digits CNN
    let dense = WeightFormat::Dense { width_bits: 32 };
    let indexed = WeightFormat::Indexed {
        index_bits: enc.conv2.codebook.index_bits(),
        bins,
        width_bits: 32,
    };
    let huff = WeightFormat::HuffmanIndexed { mean_bits, bins, width_bits: 32 };
    println!("\nconv2 weight storage ({} weights):", shape.kernels * shape.taps());
    for (name, fmt) in [("dense", &dense), ("indexed", &indexed), ("huffman", &huff)] {
        println!(
            "  {name:<8} {:>8.0} bits  ({:>5.1}x vs dense)",
            fmt.storage_bits(&shape),
            fmt.compression_vs_dense(&shape)
        );
    }
    let e_dram = weight_stream_energy(&shape, &dense, Residence::OffChipDram);
    let e_sram = weight_stream_energy(&shape, &huff, Residence::OnChipSram);
    println!(
        "\nweight-traffic energy: dense-from-DRAM {:.1} nJ vs huffman-from-SRAM {:.2} nJ ({:.0}x)",
        e_dram * 1e9,
        e_sram * 1e9,
        e_dram / e_sram
    );
    let budget = 8192.0 * 8.0; // an 8 KiB weight buffer
    println!(
        "8 KiB on-chip buffer: dense fits: {}, indexed fits: {}, huffman fits: {}",
        fits_on_chip(&shape, &dense, budget),
        fits_on_chip(&shape, &indexed, budget),
        fits_on_chip(&shape, &huff, budget)
    );

    // chain summary
    println!(
        "\nDEEP-COMPRESSION-SUMMARY acc_float={:.3} acc_pruned={:.3} acc_shared={:.3} \
         huffman_bits={:.2} compression={:.1}x",
        acc0,
        acc1,
        acc2,
        mean_bits,
        huff.compression_vs_dense(&shape)
    );
    assert!(acc2 > acc0 - 0.05, "compression should not cost >5pp accuracy");
    assert!(mean_bits <= enc.conv2.codebook.index_bits() as f64 + 1e-9);
}
