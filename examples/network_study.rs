//! Network-level study (paper §3: "any weight-shared network ... are
//! possible good candidates for the use of PASM, although the evaluation
//! in these networks is beyond the scope of this paper" — we do it here).
//!
//! For every conv layer of an AlexNet-like and a VGG-like stack, size both
//! the weight-shared and the PASM accelerator at B=16/W=32 and report the
//! per-layer savings, the amortization ratio `C·K·K / B` that predicts
//! them (Table 1/2 logic), and the latency overhead.
//!
//! ```bash
//! cargo run --release --example network_study
//! ```

use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind};
use pasm_accel::cnn::shapes::{alexnet_like, pasm_amortization, vgg_like, LayerSpec};
use pasm_accel::hw::Tech;

fn study(name: &str, layers: &[LayerSpec], bins: usize) {
    let tech = Tech::asic_1ghz();
    println!("=== {name} (B={bins}, W=32, 1 GHz) ===");
    println!(
        "{:<10} {:>6} {:>8} {:>12} {:>12} {:>9} {:>9}",
        "layer", "taps", "amort", "WS gates", "PASM gates", "gates", "latency"
    );
    let mut total_ws = 0.0;
    let mut total_pasm = 0.0;
    for l in layers {
        let ws = ConvAccel::new(ConvVariantKind::WeightShared, l.shape.clone(), bins, 32);
        let pasm = ConvAccel::new(ConvVariantKind::Pasm, l.shape.clone(), bins, 32);
        let (g_ws, g_pasm) = (ws.gates(&tech).total(), pasm.gates(&tech).total());
        total_ws += g_ws;
        total_pasm += g_pasm;
        println!(
            "{:<10} {:>6} {:>8.1} {:>12.0} {:>12.0} {:>8.1}% {:>8.1}%",
            l.name,
            l.shape.taps(),
            pasm_amortization(&l.shape, bins),
            g_ws,
            g_pasm,
            (g_pasm / g_ws - 1.0) * 100.0,
            (pasm.latency_cycles_exact() / ws.latency_cycles_exact() - 1.0) * 100.0,
        );
    }
    println!(
        "{:<10} {:>40} total {:>12.0} vs {:>12.0}: {:+.1}%\n",
        "network",
        "",
        total_ws,
        total_pasm,
        (total_pasm / total_ws - 1.0) * 100.0
    );
}

fn main() {
    for bins in [4usize, 16] {
        study("AlexNet-like conv stack", &alexnet_like(), bins);
        study("VGG-like conv stack", &vgg_like(), bins);
    }
    println!(
        "observation: at B=4 every layer wins and the network-level saving is\n\
         ~50% (the Fig 15 result generalizes); at B=16 the fully-unrolled form\n\
         hovers at breakeven under 1 GHz timing pressure — the network-level\n\
         echo of the paper's Fig 17 crossover.  The banked streaming form\n\
         (see `large_c_study` and `--bench ablation`) restores the win at\n\
         16 bins at the cost of taps-serial latency."
    );
}
