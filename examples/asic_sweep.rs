//! ASIC design-space sweep: W x B x clock for both accelerator variants,
//! with a Pareto front over (gates, power, latency) — the design guidance
//! the paper's §5.1/§5.3 gives in prose ("PASM is beneficial for up to
//! 8 weight bins at 1 GHz; target a lower clock for 16"), derived from the
//! model.
//!
//! ```bash
//! cargo run --release --example asic_sweep
//! ```

use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind};
use pasm_accel::hw::Tech;

#[derive(Clone, Debug)]
struct Point {
    label: String,
    gates: f64,
    power_w: f64,
    cycles: u64,
}

fn dominated(a: &Point, b: &Point) -> bool {
    // b dominates a
    b.gates <= a.gates
        && b.power_w <= a.power_w
        && b.cycles <= a.cycles
        && (b.gates < a.gates || b.power_w < a.power_w || b.cycles < a.cycles)
}

fn main() {
    let techs = [
        ("1GHz", Tech::asic_1ghz()),
        ("800MHz", Tech::asic_800mhz()),
        ("100MHz", Tech::asic_100mhz()),
    ];
    let mut points: Vec<Point> = Vec::new();

    println!(
        "{:<30} {:>12} {:>10} {:>8} {:>9}",
        "config", "gates", "power", "cycles", "PASM vs WS"
    );
    for (tname, tech) in &techs {
        for bins in [4usize, 8, 16] {
            for ww in [8u32, 16, 32] {
                let ws = ConvAccel::paper(ConvVariantKind::WeightShared, bins, ww);
                let pasm = ConvAccel::paper(ConvVariantKind::Pasm, bins, ww);
                let ws_g = ws.gates(tech).total();
                let pasm_g = pasm.gates(tech).total();
                for (vname, a, g) in
                    [("WS", &ws, ws_g), ("PASM", &pasm, pasm_g)]
                {
                    let p = a.power(tech).total_w();
                    let label = format!("{vname}/{ww}b/{bins}bin@{tname}");
                    println!(
                        "{label:<30} {g:>12.0} {:>8.2}mW {:>8} {:>9}",
                        p * 1e3,
                        a.latency_cycles(),
                        if vname == "PASM" {
                            format!("{:+.1}%", (pasm_g / ws_g - 1.0) * 100.0)
                        } else {
                            String::from("-")
                        }
                    );
                    points.push(Point { label, gates: g, power_w: p, cycles: a.latency_cycles() });
                }
            }
        }
    }

    // Pareto front over (gates, power, cycles)
    let front: Vec<&Point> = points
        .iter()
        .filter(|a| !points.iter().any(|b| dominated(a, b)))
        .collect();
    println!("\nPareto-optimal configurations ({} of {}):", front.len(), points.len());
    for p in &front {
        println!(
            "  {:<30} {:>12.0} gates {:>8.2} mW {:>6} cycles",
            p.label,
            p.gates,
            p.power_w * 1e3,
            p.cycles
        );
    }

    // the paper's prose conclusions, checked
    let t1g = Tech::asic_1ghz();
    let win8 = ConvAccel::paper(ConvVariantKind::Pasm, 8, 32).gates(&t1g).total()
        < ConvAccel::paper(ConvVariantKind::WeightShared, 8, 32).gates(&t1g).total();
    let lose16 = ConvAccel::paper(ConvVariantKind::Pasm, 16, 32).gates(&t1g).total()
        > ConvAccel::paper(ConvVariantKind::WeightShared, 16, 32).gates(&t1g).total();
    println!("\n1 GHz: PASM wins at 8 bins: {win8}; loses at 16 bins: {lose16} (paper §5.1)");
}
