//! Large-C what-if (paper footnote 1): with SRAM-backed caches, the image
//! tile can grow far beyond the register-file-bound C=15, and the PASM
//! post-pass amortizes over more accumulations.
//!
//! The natural micro-architecture at large C is the *streaming* one (the
//! §5.3 banked form — you cannot unroll 4608 taps): one tap per cycle
//! through a single datapath, `N = C·K·K` cycles per output plus `B`
//! post-pass cycles.  Footnote 1's claim is an amortization claim, and it
//! shows up in two curves:
//!
//!   * the PASM latency overhead `B / N` vanishes as C grows;
//!   * the PASM energy advantage grows: the multiplier only fires for the
//!     `B` post-pass cycles out of `N + B`, so its duty → 0.
//!
//! Plus the enabler: an SRAM macro of the cache's capacity costs a small
//! fraction of the register file the paper was forced to use.
//!
//! ```bash
//! cargo run --release --example large_c_study
//! ```

use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind, IMAGE_WIDTH};
use pasm_accel::accel::hls::HlsConfig;
use pasm_accel::hw::sram::{register_cost_nand2, SramMacro};
use pasm_accel::hw::Tech;
use pasm_accel::tensor::ConvShape;

fn banked(variant: ConvVariantKind, shape: ConvShape, bins: usize) -> ConvAccel {
    let mut a = ConvAccel::new(variant, shape, bins, 32);
    a.hls = HlsConfig { unroll_taps: false, partition_bins: false, ..HlsConfig::default() };
    a.sram_cache = true; // footnote 1: SRAM makes the large tile affordable
    a
}

fn main() {
    let tech = Tech::asic_1ghz();
    let bins = 16usize;
    println!("streaming (banked) accelerators, B={bins}, W=32, 3x3, M=2, 5x5 tile, 1 GHz\n");
    println!(
        "{:>5} {:>10} {:>12} {:>12} {:>10} {:>10} {:>9} {:>10}",
        "C", "cache bits", "cache(regs)", "cache(SRAM)", "WS energy", "PASM energy", "saving", "lat ovhd"
    );

    let mut lat_overheads = Vec::new();
    let mut energy_savings = Vec::new();
    for c in [15usize, 32, 64, 128, 256, 512] {
        let shape = ConvShape::new(c, 5, 5, 3, 3, 2, 1);
        let cache_bits = (c * 5 * 5) as u64 * IMAGE_WIDTH as u64;
        let sram = SramMacro::new(cache_bits, 2);
        let ws = banked(ConvVariantKind::WeightShared, shape.clone(), bins);
        let pasm = banked(ConvVariantKind::Pasm, shape, bins);
        // energy per full layer: power x time
        let e = |a: &ConvAccel| {
            a.power(&tech).total_w() * a.latency_cycles_exact() * tech.period_s()
        };
        let (e_ws, e_pasm) = (e(&ws), e(&pasm));
        let lat = pasm.latency_cycles_exact() / ws.latency_cycles_exact() - 1.0;
        println!(
            "{c:>5} {cache_bits:>10} {:>12.0} {:>12.0} {:>9.2}nJ {:>9.2}nJ {:>8.1}% {:>9.2}%",
            register_cost_nand2(cache_bits),
            sram.area_nand2(),
            e_ws * 1e9,
            e_pasm * 1e9,
            (1.0 - e_pasm / e_ws) * 100.0,
            lat * 100.0
        );
        lat_overheads.push(lat);
        energy_savings.push(1.0 - e_pasm / e_ws);
    }

    // footnote-1 checks
    assert!(
        lat_overheads.windows(2).all(|w| w[1] < w[0]),
        "latency overhead must shrink with C: {lat_overheads:?}"
    );
    assert!(
        energy_savings.last().unwrap() > energy_savings.first().unwrap(),
        "energy advantage must grow with C: {energy_savings:?}"
    );
    let big_bits = 512u64 * 25 * IMAGE_WIDTH as u64;
    assert!(SramMacro::new(big_bits, 2).area_nand2() < register_cost_nand2(big_bits) / 5.0);
    println!(
        "\nfootnote-1 reproduced: latency overhead {:.2}% -> {:.2}% and energy\n\
         saving {:.1}% -> {:.1}% as C goes 15 -> 512; SRAM keeps the cache >5x\n\
         cheaper than registers.",
        lat_overheads[0] * 100.0,
        lat_overheads.last().unwrap() * 100.0,
        energy_savings[0] * 100.0,
        energy_savings.last().unwrap() * 100.0
    );
}
