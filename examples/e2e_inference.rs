//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! 1. Generate a synthetic 10-class digit dataset (train/test).
//! 2. Train the float digits CNN in rust (SGD, hand-written backprop).
//! 3. K-means-quantize both conv layers to B=16 shared weights
//!    (deep-compression style — the paper's precondition).
//! 4. Serve a batch of inference requests through the **coordinator**:
//!    numerics on the configured execution backend (the in-process
//!    `NativeBackend` by default; the AOT-lowered PJRT/Pallas model with
//!    `--features pjrt` after `make artifacts`), hardware cost on the
//!    45 nm PASM accelerator model.
//! 5. Verify: PASM ≡ WS numerics (paper §5.3), quantized accuracy ≈ float
//!    accuracy (Han et al.'s observation), and report latency/throughput.
//!
//! ```bash
//! cargo run --release --example e2e_inference
//! ```

use pasm_accel::cnn::data::{train_test, Rng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::cnn::train::{train, TrainConfig};
use pasm_accel::coordinator::{default_backend, BatchPolicy, CoordinatorBuilder};
use pasm_accel::quant::fixed::QFormat;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    // ---- 1) data ----
    let (train_set, test_set) = train_test(2024, 600, 200, 0.05);
    println!("dataset: {} train / {} test synthetic digits", train_set.len(), test_set.len());

    // ---- 2) train float ----
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(17);
    let mut params = arch.init(&mut rng);
    let cfg = TrainConfig { epochs: 25, lr: 0.05, momentum: 0.9, log_every: 5 };
    let t0 = Instant::now();
    let stats = train(&arch, &mut params, &train_set, &cfg);
    let float_acc = arch.accuracy(&params, &test_set);
    println!(
        "trained {} epochs in {:?}: final loss {:.4}, float test accuracy {:.1}%",
        stats.len(),
        t0.elapsed(),
        stats.last().unwrap().mean_loss,
        float_acc * 100.0
    );

    // ---- 3) weight sharing ----
    let bins = 16;
    let enc = EncodedCnn::encode(arch, &params, bins, QFormat::W32);
    println!(
        "quantized to B={bins}: conv1 mse {:.2e}, conv2 mse {:.2e}, occupancy {:?}",
        enc.conv1.mse,
        enc.conv2.mse,
        enc.conv1.occupancy()
    );
    let ws_acc = enc.accuracy(&test_set, ConvVariant::WeightShared);
    let pasm_acc = enc.accuracy(&test_set, ConvVariant::Pasm);
    println!(
        "quantized accuracy: WS {:.1}%, PASM {:.1}% (float {:.1}%)",
        ws_acc * 100.0,
        pasm_acc * 100.0,
        float_acc * 100.0
    );
    assert!(
        (ws_acc - pasm_acc).abs() < 1e-9,
        "paper §5.3: PASM must not change accuracy vs WS"
    );

    // ---- 4) serve through the coordinator ----
    let coord = CoordinatorBuilder::new()
        .boxed_backend(default_backend("artifacts", enc.clone()))
        .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2)))
        .build()?;
    let backend_name = coord.metrics().backend;
    let t0 = Instant::now();
    let rxs: Vec<_> = test_set
        .iter()
        .map(|s| coord.submit(s.image.clone()).unwrap())
        .collect();
    let mut correct = 0usize;
    let mut agree = 0usize;
    for (s, rx) in test_set.iter().zip(rxs) {
        let resp = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        if resp.predicted == s.label {
            correct += 1;
        }
        // serving backend vs in-process rust reference
        let want = enc.forward(&s.image, ConvVariant::Pasm);
        if resp.predicted == pasm_accel::cnn::layer::argmax(&want) {
            agree += 1;
        }
    }
    let dt = t0.elapsed();
    let served_acc = correct as f64 / test_set.len() as f64;
    println!(
        "served {} requests in {:?} ({:.1} req/s) on '{}': accuracy {:.1}%, backend/reference agreement {}/{}",
        test_set.len(),
        dt,
        test_set.len() as f64 / dt.as_secs_f64(),
        backend_name,
        served_acc * 100.0,
        agree,
        test_set.len()
    );
    assert_eq!(agree, test_set.len(), "backend and rust reference forward must agree");

    // ---- 5) metrics + hardware cost ----
    let m = coord.metrics();
    println!(
        "batches: {} (mean occupancy {:.1}, padding {:.1}%)",
        m.batches,
        m.mean_occupancy(),
        m.padding_fraction() * 100.0
    );
    for p in [50.0, 90.0, 99.0] {
        println!("p{p:.0} latency: {} us", m.percentile_us(p).unwrap());
    }
    println!(
        "simulated PASM accelerator: {} cycles total, {:.3} uJ ({:.2} nJ/request)",
        m.sim_cycles,
        m.sim_energy_j * 1e6,
        m.sim_energy_j * 1e9 / test_set.len() as f64
    );

    // summary line for the experiment log
    println!(
        "\nE2E-SUMMARY backend={} float_acc={:.3} ws_acc={:.3} pasm_acc={:.3} served_acc={:.3} req_per_s={:.1} p50_us={} sim_cycles={} sim_uJ={:.3}",
        backend_name,
        float_acc,
        ws_acc,
        pasm_acc,
        served_acc,
        test_set.len() as f64 / dt.as_secs_f64(),
        m.percentile_us(50.0).unwrap(),
        m.sim_cycles,
        m.sim_energy_j * 1e6
    );
    Ok(())
}
